"""Build a replica set, run a workload, gather stats (paper run_with_params).

This is the entry point used by tests, benchmarks, and examples. Given
(RaftParams, SimParams, seed) it is fully deterministic.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from .checker import check_linearizability
from .client import ClientLogEntry, Directory, Workload
from .clock import BoundedClock
from .network import NetParams, Network
from .params import RaftParams, SimParams
from .prob import PRNG
from .raft import Node
from .simulate import EventLoop


@dataclass
class Cluster:
    loop: EventLoop
    net: Network
    nodes: dict[int, Node]
    directory: Directory
    prng: PRNG

    def leader(self) -> Optional[Node]:
        lid = self.directory.leader_id
        return self.nodes.get(lid) if lid is not None else None

    def wait_for_leader(self, max_time: float = 10.0) -> Node:
        deadline = self.loop.now + max_time
        while self.loop.now < deadline:
            self.loop.run_until(self.loop.now + 0.01)
            for n in self.nodes.values():
                if n.is_leader():
                    return n
        raise RuntimeError("no leader elected")

    def spawn_node(self, node_id: int, raft: RaftParams,
                   max_clock_error: float = 50e-6) -> Node:
        """Create a fresh follower (elastic scaling; it joins the replica
        set once a leader commits the CONFIG entry that includes it)."""
        from .clock import BoundedClock
        clock = BoundedClock(self.loop, self.prng.fork(600 + node_id),
                             max_clock_error)
        node = Node(node_id, self.loop, self.net, clock,
                    self.prng.fork(700 + node_id), raft,
                    [node_id],        # starts alone; adopts config from log
                    on_leader=self.directory.on_leader)
        self.nodes[node_id] = node
        return node


def build_cluster(raft: RaftParams, sim: SimParams,
                  clock_faults: Optional[dict[int, float]] = None) -> Cluster:
    loop = EventLoop()
    prng = PRNG(sim.seed)
    net = Network(loop, prng.fork(101), NetParams(
        one_way_latency_mean=sim.one_way_latency_mean,
        one_way_latency_variance=sim.one_way_latency_variance,
        io_service_time=sim.io_service_time,
        rpc_timeout=raft.rpc_timeout,
    ))
    directory = Directory()
    ids = list(range(raft.n_nodes))
    nodes = {}
    for i in ids:
        fault = (clock_faults or {}).get(i, 0.0)
        clock = BoundedClock(loop, prng.fork(200 + i), raft.max_clock_error,
                             faulty=fault != 0.0, fault_skew=fault)
        nodes[i] = Node(i, loop, net, clock, prng.fork(300 + i), raft, ids,
                        on_leader=directory.on_leader)
    return Cluster(loop, net, nodes, directory, prng)


@dataclass
class RunResult:
    history: list[ClientLogEntry]
    reads_ok: int = 0
    reads_fail: int = 0
    writes_ok: int = 0
    writes_fail: int = 0
    read_latencies: list[float] = field(default_factory=list)
    write_latencies: list[float] = field(default_factory=list)
    linearizable_ops: int = 0

    def summarize(self) -> dict:
        import statistics as st

        def pct(xs, q):
            if not xs:
                return float("nan")
            xs = sorted(xs)
            k = min(len(xs) - 1, int(q * len(xs)))
            return xs[k]

        return {
            "reads_ok": self.reads_ok, "reads_fail": self.reads_fail,
            "writes_ok": self.writes_ok, "writes_fail": self.writes_fail,
            "read_p50": pct(self.read_latencies, 0.50),
            "read_p90": pct(self.read_latencies, 0.90),
            "write_p50": pct(self.write_latencies, 0.50),
            "write_p90": pct(self.write_latencies, 0.90),
            "read_mean": st.fmean(self.read_latencies) if self.read_latencies else float("nan"),
            "write_mean": st.fmean(self.write_latencies) if self.write_latencies else float("nan"),
        }


def run_workload(raft: RaftParams, sim: SimParams,
                 fault_script: Optional[Callable[[Cluster], None]] = None,
                 check: bool = True,
                 settle_time: float = 1.0) -> RunResult:
    """End-to-end deterministic run.

    ``fault_script(cluster)`` may schedule crashes/partitions on the loop
    before the workload starts (paper §6.5 crashes the leader at t=0.5s).
    """
    cluster = build_cluster(raft, sim)
    loop = cluster.loop
    cluster.wait_for_leader()
    t0 = loop.now
    workload = Workload(loop, cluster.nodes, cluster.directory,
                        cluster.prng.fork(999), sim)
    if fault_script is not None:
        fault_script(cluster)
    loop.create_task(workload.run(sim.sim_duration))
    loop.run_until(t0 + sim.sim_duration + settle_time)
    history = workload.finalize()

    res = RunResult(history=history)
    for op in history:
        lat = op.end_ts - op.start_ts
        if op.op_type == "Read":
            if op.success:
                res.reads_ok += 1
                res.read_latencies.append(lat)
            else:
                res.reads_fail += 1
        else:
            if op.success:
                res.writes_ok += 1
                res.write_latencies.append(lat)
            else:
                res.writes_fail += 1
    if check:
        res.linearizable_ops = check_linearizability(history)
    return res


def throughput_timeline(history: list[ClientLogEntry], bin_size: float,
                        t_start: float, t_end: float) -> list[dict]:
    """Per-bin successful read/write counts — the paper's availability plots."""
    n_bins = int((t_end - t_start) / bin_size) + 1
    bins = [{"t": t_start + i * bin_size, "reads": 0, "writes": 0,
             "read_fail": 0, "write_fail": 0} for i in range(n_bins)]
    for op in history:
        i = int((op.end_ts - t_start) / bin_size)
        if 0 <= i < n_bins:
            b = bins[i]
            if op.op_type == "Read":
                b["reads" if op.success else "read_fail"] += 1
            else:
                b["writes" if op.success else "write_fail"] += 1
    return bins

"""Elastic scaling of the coordinator through the public API."""

from repro.coord.kvstore import LocalCoordinator
from repro.core.raft import CONFIG, parse_config


def test_coordinator_scale_up_down():
    coord = LocalCoordinator()
    coord.append("k", 1)
    new_id = coord.scale_up()
    assert coord.read_latest("k") == 1
    coord.append("k", 2)
    ldr = coord._leader()
    assert new_id in ldr.config and len(ldr.config) == 4
    # scale back down (pick a non-leader member)
    victim = next(i for i in ldr.config if i not in (ldr.id,))
    coord.scale_down(victim)
    assert len(coord._leader().config) == 3
    assert coord.read_latest("k") == 2


def test_add_node_goes_through_learner_stage():
    """add_node is the safe two-step: join as non-voting learner, then
    get promoted to voter by the leader once caught up."""
    coord = LocalCoordinator()
    for i in range(5):
        coord.append("k", i)
    new_id = coord.add_node()
    ldr = coord._leader()
    assert new_id in ldr.config and not ldr.learners
    # the replicated config history shows learner-then-voter, in order
    configs = [parse_config(e.value) for e in ldr.log if e.key == CONFIG]
    joined = [i for i, (_, l) in enumerate(configs) if new_id in l]
    promoted = [i for i, (v, _) in enumerate(configs) if new_id in v]
    assert joined and promoted and joined[0] < promoted[0]
    assert coord.read_latest("k") == 4
    # and the newcomer's state machine really caught up
    assert coord.cluster.nodes[new_id].data == ldr.data


def test_remove_node_targeting_leader_does_handover():
    """Regression: remove_node(leader) used to fail — a leader cannot
    remove itself. It now relinquishes leadership (planned handover) and
    the successor performs the removal."""
    coord = LocalCoordinator()
    coord.append("k", 1)
    coord.add_node()                       # 4 voters: removal keeps quorum 2
    old_leader = coord._leader().id
    coord.remove_node(old_leader)
    ldr = coord._leader()
    assert ldr.id != old_leader
    assert old_leader not in ldr.config
    assert old_leader not in ldr.learners
    coord.append("k", 2)                   # cluster still fully functional
    assert coord.read_latest("k") == 2


def test_scaled_up_cluster_tolerates_extra_failure():
    coord = LocalCoordinator()
    coord.append("k", 1)
    coord.scale_up()
    coord.scale_up()                       # now 5 nodes: tolerates 2 faults
    ldr = coord._leader()
    assert len(ldr.config) == 5
    followers = [n for n in coord.cluster.nodes.values()
                 if n.alive and n is not ldr][:2]
    for f in followers:
        f.crash()
    coord.append("k", 2)
    assert coord.read_latest("k") == 2

"""Policy × scenario × seed linearizability/availability matrix.

Runs every registered consistency policy against every named nemesis
scenario over many seeds, pushes each history through the omniscient
checker, and writes ``BENCH_fault_matrix.json`` at the repo root.
Reduced slices (``--smoke``, ``--policies``, ``--scenarios``, fewer
seeds) write ``BENCH_fault_matrix_smoke.json`` instead, so they never
clobber the committed full-cube artifact.

The contract the matrix enforces (and CI smoke-checks):

* every **consistent** policy × every **safe** scenario × every seed is
  linearizable — zero violations;
* the **inconsistent** baseline produces detected violations under
  partition scenarios — the positive control proving the checker bites;
* identical (seed, scenario, policy) reruns are bit-identical, so the
  JSON artifact is a stable perf/safety trajectory across PRs.

Usage:
    python benchmarks/fault_matrix.py [--seeds N] [--smoke] [--warm-start]
        [--scenarios a,b] [--policies x,y] [--include-unsafe] [--jobs N]

``--warm-start`` restores a cached post-election snapshot per policy
instead of booting + electing per seed (see ``repro.core.runner``);
histories differ from the cold sweep but verdicts must match, which the
flag checks against the committed ``BENCH_fault_matrix.json``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.consistency import benchmark_configs, split_bench_config  # noqa: E402
from repro.core import (LinearizabilityError, RaftParams, SimParams,  # noqa: E402
                        check_linearizability, run_workload,
                        throughput_timeline)
from repro.faults import (build_scenario, safe_scenario_names,  # noqa: E402
                          unsafe_scenario_names)

REPO_ROOT = Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_fault_matrix.json"
# reduced slices must not clobber the committed full-cube artifact
SMOKE_OUT_PATH = REPO_ROOT / "BENCH_fault_matrix_smoke.json"
# warm-start sweeps have different histories (same verdicts); keep them
# out of the committed cold artifact too
WARM_OUT_PATH = REPO_ROOT / "BENCH_fault_matrix_warm.json"

#: policies with no linearizability claim — exempt from the zero-violation
#: assertion (and expected to violate under partitions).
NON_LINEARIZABLE = {"inconsistent"}

#: scenarios under which the inconsistent baseline is expected to produce
#: checker-detected stale reads (the positive control).
PARTITION_SCENARIOS = {
    "leader_crash_restart", "leader_nemesis", "asym_partition_leader_deaf",
    "asym_partition_leader_mute", "majority_minority",
}

DEFAULT_SEEDS = 20
SIM_DURATION = 1.2
SETTLE_TIME = 1.5
#: availability-curve bin width (seconds) for the per-cell timeline
TIMELINE_BIN = 0.1


def policy_configs() -> dict[str, dict]:
    """One canonical config per registered policy (no ablation variants).
    The inconsistent baseline gets a slice of follower-routed reads so
    partition scenarios can actually produce the stale reads it allows."""
    configs = benchmark_configs(variants=False)
    inco = configs.get("inconsistent")
    if inco is not None:
        sim = dict(inco.get("sim_params", {}))
        sim.setdefault("follower_read_fraction", 0.3)
        inco["sim_params"] = sim
    return configs


def run_cell(policy: str, scenario_name: str, seed: int,
             warm_start: bool = False, trace: bool = False,
             trace_dir: str = None) -> dict:
    """One deterministic run; returns a JSON-ready row.

    ``trace=True`` records the cell with the flight recorder
    (``repro.obs``) — the row gains the lease-probe verdict and a
    compact forensic digest, and ``trace_dir`` (if given) receives the
    full JSONL + Chrome-trace dumps. Tracing never draws from any PRNG,
    so traced rows carry the exact same history-derived fields as
    untraced ones. Untraced cells that the checker flags are re-run
    traced (identical replay) so the committed artifact embeds the
    digest naming the causal election/partition for every violation.
    """
    flags, sim_flags = split_bench_config(policy_configs()[policy])
    sc = build_scenario(scenario_name)
    # a scenario may require RaftParams flags for its expect_safe
    # classification (corruption tier: entry_checksums); scenarios with
    # no overrides build the exact historical params
    raft = RaftParams(election_timeout=0.3, election_jitter=0.1,
                      heartbeat_interval=0.03, lease_duration=0.6,
                      rpc_timeout=0.15, **{**flags, **sc.raft_overrides})
    sim = SimParams(seed=seed, sim_duration=SIM_DURATION, interarrival=3e-3,
                    write_fraction=1 / 3, **sim_flags)
    res = run_workload(raft, sim, fault_script=sc.install, check=False,
                       settle_time=SETTLE_TIME, warm_start=warm_start,
                       trace=trace)
    try:
        checked = check_linearizability(res.history)
        violation = None
    except LinearizabilityError as e:
        checked = 0
        violation = str(e)[:200]
    ok = res.reads_ok + res.writes_ok
    fail = res.reads_fail + res.writes_fail
    # compact availability curve: ok/fail op counts per TIMELINE_BIN-wide
    # window from workload start, so failover dips (and how fast each
    # policy recovers) are visible in the artifact, not just verdicts
    bins = throughput_timeline(res.history, TIMELINE_BIN, res.t_start,
                               res.t_start + SIM_DURATION + SETTLE_TIME)
    row = {
        "policy": policy,
        "scenario": scenario_name,
        "seed": seed,
        "ops_ok": ok,
        "ops_fail": fail,
        "reads_ok": res.reads_ok,
        "writes_ok": res.writes_ok,
        "availability": round(ok / max(1, ok + fail), 4),
        "checked_ops": checked,
        "violation": violation,
        "timeline": {
            "bin_size": TIMELINE_BIN,
            "t0": round(res.t_start, 9),
            "ok": [b["reads"] + b["writes"] for b in bins],
            "fail": [b["read_fail"] + b["write_fail"] for b in bins],
        },
    }
    if trace:
        row.update(_trace_fields(policy, scenario_name, seed, sc, res,
                                 res.trace or [], trace_dir))
    elif violation:
        # forensic rerun: tracing is draw-order-neutral, so the traced
        # rerun replays this exact history and the digest pins the
        # causal election/partition behind the flagged violation
        from repro.obs.explain import trace_digest
        tres = run_workload(raft, sim,
                            fault_script=build_scenario(scenario_name).install,
                            check=False, settle_time=SETTLE_TIME,
                            warm_start=warm_start, trace=True)
        row["trace_digest"] = trace_digest(tres.trace or [],
                                           tres.t_start, tres.t_end)
    return row


def _trace_fields(policy: str, scenario_name: str, seed: int, sc, res,
                  events: list, trace_dir: str = None) -> dict:
    from repro.obs import at_most_one_lease_holder
    from repro.obs.explain import trace_digest
    probe = at_most_one_lease_holder(events)
    out = {
        "trace_events": len(events),
        "lease_probe_violations": len(probe),
        "trace_digest": trace_digest(events, res.t_start, res.t_end),
    }
    if trace_dir:
        from repro.obs.export import write_chrome_trace, write_jsonl
        d = Path(trace_dir)
        d.mkdir(parents=True, exist_ok=True)
        stem = f"{policy}__{scenario_name}__s{seed}"
        write_jsonl(events, d / f"{stem}.jsonl", policy=policy,
                    scenario=scenario_name, seed=seed,
                    expect_safe=sc.expect_safe)
        write_chrome_trace(events, d / f"{stem}.chrome.json", t_end=res.t_end)
        out["trace_file"] = str(d / f"{stem}.jsonl")
    return out


def _cell_args(policies, scenarios, seeds, warm_start=False, trace=False,
               trace_dir=None):
    return [(p, s, seed, warm_start, trace, trace_dir)
            for p in policies for s in scenarios for seed in seeds]


def run_matrix(policies: list[str], scenarios: list[str], seeds: list[int],
               jobs: int = 1, progress: bool = True,
               warm_start: bool = False, trace: bool = False,
               trace_dir: str = None) -> list[dict]:
    """Run the cube; byte-identical output for any ``jobs``.

    Parallel runs shard the canonical cell list round-robin (cell i ->
    shard i mod jobs), each worker runs its shard in order, and the
    shards are de-interleaved back into canonical cell order before the
    final canonical sort — every cell is an independent deterministic
    simulation, so only ordering could differ, and ordering is pinned."""
    cells = _cell_args(policies, scenarios, seeds, warm_start, trace,
                       trace_dir)
    if jobs > 1:
        from concurrent.futures import ProcessPoolExecutor
        shards = [cells[k::jobs] for k in range(jobs)]
        with ProcessPoolExecutor(max_workers=jobs) as ex:
            shard_rows = list(ex.map(_run_shard, shards))
        # ordered merge: undo the round-robin interleave
        iters = [iter(sr) for sr in shard_rows]
        rows = [next(iters[i % jobs]) for i in range(len(cells))]
    else:
        rows = []
        for i, cell in enumerate(cells):
            rows.append(run_cell(*cell))
            if progress and (i + 1) % 50 == 0:
                print(f"# {i + 1}/{len(cells)} cells", file=sys.stderr)
    rows.sort(key=lambda r: (r["policy"], r["scenario"], r["seed"]))
    return rows


def _run_shard(cells) -> list[dict]:
    return [run_cell(*cell) for cell in cells]


def summarize(rows: list[dict]) -> list[dict]:
    """Per (policy, scenario): seeds, violations, mean availability."""
    agg: dict[tuple[str, str], dict] = {}
    for r in rows:
        a = agg.setdefault((r["policy"], r["scenario"]), {
            "policy": r["policy"], "scenario": r["scenario"], "seeds": 0,
            "violations": 0, "ops_ok": 0, "ops_fail": 0,
        })
        a["seeds"] += 1
        a["violations"] += 1 if r["violation"] else 0
        a["ops_ok"] += r["ops_ok"]
        a["ops_fail"] += r["ops_fail"]
    out = []
    for key in sorted(agg):
        a = agg[key]
        a["availability"] = round(
            a["ops_ok"] / max(1, a["ops_ok"] + a["ops_fail"]), 4)
        out.append(a)
    return out


class FaultMatrixError(AssertionError):
    """The matrix contract failed: a consistent policy violated
    linearizability under a safe scenario, or the positive control
    (inconsistent flagged under partitions) came up empty."""


def check_verdict_parity(warm: dict, cold: dict) -> list[str]:
    """Compare a warm-start artifact against the committed cold one.

    Warm histories legitimately differ from cold (the boot phase is
    shared and PRNG streams are re-keyed), so parity is defined on
    *verdicts*: every consistent-policy (policy, scenario) pair must be
    violation-free in both, and the inconsistent positive control must
    be flagged in both (aggregate — per-seed flag patterns may differ).
    Returns a list of human-readable mismatches (empty = parity holds).
    """
    problems: list[str] = []
    key = lambda s: (s["policy"], s["scenario"])  # noqa: E731
    warm_sum = {key(s): s for s in warm["summary"]}
    cold_sum = {key(s): s for s in cold["summary"]}
    shared = sorted(set(warm_sum) & set(cold_sum))
    if not shared:
        return ["no overlapping (policy, scenario) pairs to compare"]
    consistent = set(cold.get("consistent_policies", []))
    for k in shared:
        if k[0] in consistent:
            w, c = warm_sum[k]["violations"], cold_sum[k]["violations"]
            if (w > 0) != (c > 0):
                problems.append(
                    f"{k[0]}/{k[1]}: warm violations={w}, cold={c}")
    # compare the positive control only when the warm sweep actually ran
    # the baseline against partitions over enough seeds to arm it
    control_armed = (set(warm.get("policies", [])) & NON_LINEARIZABLE
                     and set(warm.get("scenarios", [])) & PARTITION_SCENARIOS
                     and len(warm.get("seeds", [])) >= 10)
    if control_armed:
        w_ctl = warm.get("inconsistent_violations", 0)
        c_ctl = cold.get("inconsistent_violations", 0)
        if (w_ctl > 0) != (c_ctl > 0):
            problems.append(f"positive control: warm flagged {w_ctl} cells, "
                            f"cold flagged {c_ctl}")
    return problems


def run(quick: bool = False) -> list[dict]:
    """benchmarks.run entry point: full matrix, or the CI smoke slice."""
    return main(["--smoke"] if quick else [])


def main(argv=None) -> list[dict]:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seeds", type=int, default=DEFAULT_SEEDS,
                    help=f"seeds per cell (default {DEFAULT_SEEDS})")
    ap.add_argument("--scenarios", default=None,
                    help="comma-separated scenario names (default: all safe)")
    ap.add_argument("--policies", default=None,
                    help="comma-separated policy names (default: all)")
    ap.add_argument("--include-unsafe", action="store_true",
                    help="also run the beyond-fault-model scenarios")
    ap.add_argument("--smoke", action="store_true",
                    help="CI slice: 2 scenarios x 2 policies x 5 seeds")
    ap.add_argument("--warm-start", action="store_true",
                    help="amortize one post-election cluster snapshot per "
                         "(policy) across seeds; writes "
                         "BENCH_fault_matrix_warm.json and checks verdict "
                         "parity against the committed cold artifact")
    ap.add_argument("--trace", action="store_true",
                    help="record every cell with the flight recorder "
                         "(repro.obs): rows gain lease-probe verdicts + "
                         "forensic digests, and the probe is enforced on "
                         "consistent policies under safe scenarios")
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="also dump per-cell JSONL + Chrome traces to DIR "
                         "(implies --trace)")
    ap.add_argument("--jobs", type=int,
                    default=max(1, (os.cpu_count() or 2) - 1))
    ap.add_argument("--out", default=None,
                    help="artifact path (default: BENCH_fault_matrix.json; "
                         "reduced slices go to BENCH_fault_matrix_smoke.json)")
    args = ap.parse_args(argv)
    if args.trace_dir:
        args.trace = True

    all_policies = list(policy_configs())
    scenarios = safe_scenario_names()
    policies = all_policies
    seeds = list(range(args.seeds))
    if args.include_unsafe:
        scenarios = scenarios + unsafe_scenario_names()
    if args.smoke:
        # one scenario per failure-model tier rides in CI on every push:
        # crash-stop (crash, split, churn, disk loss), gray (flapping),
        # corruption (checksummed)
        scenarios = ["leader_crash_restart", "majority_minority",
                     "membership_churn", "disk_loss_safe",
                     "flapping_node", "corrupt_entries_checked"]
        policies = ["leaseguard", "quorum"]
        seeds = list(range(5))
    if args.scenarios:
        scenarios = args.scenarios.split(",")
    if args.policies:
        policies = args.policies.split(",")
    # only the canonical cube (every policy x every safe scenario x at
    # least the default seed count, no unsafe pollution) may overwrite
    # the committed artifact; every reduced/expanded slice goes to the
    # smoke path unless --out says otherwise
    full_cube = (not args.smoke and not args.scenarios and not args.policies
                 and not args.include_unsafe and not args.trace
                 and args.seeds >= DEFAULT_SEEDS)
    if args.warm_start:
        out_path = args.out or str(WARM_OUT_PATH if full_cube
                                   else SMOKE_OUT_PATH)
    else:
        out_path = args.out or str(OUT_PATH if full_cube else SMOKE_OUT_PATH)

    n = len(policies) * len(scenarios) * len(seeds)
    print(f"# fault matrix: {len(policies)} policies x {len(scenarios)} "
          f"scenarios x {len(seeds)} seeds = {n} cells "
          f"(jobs={args.jobs}{', warm-start' if args.warm_start else ''}"
          f"{', traced' if args.trace else ''})",
          file=sys.stderr)
    rows = run_matrix(policies, scenarios, seeds, jobs=args.jobs,
                      warm_start=args.warm_start, trace=args.trace,
                      trace_dir=args.trace_dir)
    summary = summarize(rows)

    consistent = [p for p in policies if p not in NON_LINEARIZABLE]
    safe = set(safe_scenario_names())
    bad = [r for r in rows
           if r["violation"] and r["policy"] in consistent
           and r["scenario"] in safe]
    control = [r for r in rows
               if r["violation"] and r["policy"] in NON_LINEARIZABLE]
    # the positive control only has teeth when the baseline actually ran
    # against partitions over enough seeds to make a stale read likely
    control_expected = (set(policies) & NON_LINEARIZABLE
                        and set(scenarios) & PARTITION_SCENARIOS
                        and len(seeds) >= 10)

    artifact = {
        "policies": policies,
        "scenarios": scenarios,
        "seeds": seeds,
        "warm_start": args.warm_start,
        "consistent_policies": consistent,
        "consistent_violations": len(bad),
        "inconsistent_violations": len(control),
        "summary": summary,
    }
    if args.warm_start:
        # warm sweeps are a throughput vehicle, not the canonical record:
        # the artifact keeps verdict-level evidence only (the cold matrix
        # holds the per-cell histories' stats + availability timelines)
        artifact["n_cells"] = len(rows)
    else:
        artifact["cells"] = rows
    Path(out_path).write_text(json.dumps(artifact, indent=2, sort_keys=True)
                              + "\n")
    print(f"# wrote {out_path}", file=sys.stderr)

    if args.warm_start and OUT_PATH.exists():
        cold = json.loads(OUT_PATH.read_text())
        problems = check_verdict_parity(artifact, cold)
        if problems:
            msg = ("warm-start verdicts diverge from the committed cold "
                   "matrix: " + "; ".join(problems[:5]))
            print(f"\nFAIL: {msg}", file=sys.stderr)
            raise FaultMatrixError(msg)
        print("# warm-start verdicts match the committed cold matrix",
              file=sys.stderr)

    for s in summary:
        print(f"{s['policy']:14s} {s['scenario']:28s} "
              f"seeds={s['seeds']:3d} violations={s['violations']:3d} "
              f"availability={s['availability']:.3f}")
    if bad:
        msg = (f"{len(bad)} linearizability violations in consistent "
               f"policies under safe scenarios")
        print(f"\nFAIL: {msg}:", file=sys.stderr)
        for r in bad[:10]:
            print(f"  {r['policy']} / {r['scenario']} / seed {r['seed']}: "
                  f"{r['violation']}", file=sys.stderr)
        raise FaultMatrixError(msg)
    if control_expected and not control:
        msg = ("positive control failed: the inconsistent baseline was "
               "never flagged under partition scenarios — is the checker "
               "vacuous?")
        print(f"\nFAIL: {msg}", file=sys.stderr)
        raise FaultMatrixError(msg)
    if args.trace:
        # second, mechanism-level safety net: the offline lease probe must
        # clear every consistent-policy cell inside the fault model
        probe_bad = [r for r in rows
                     if r.get("lease_probe_violations")
                     and r["policy"] in consistent and r["scenario"] in safe]
        if probe_bad:
            msg = (f"lease probe: {len(probe_bad)} consistent-policy cells "
                   f"show overlapping exclusive lease windows")
            print(f"\nFAIL: {msg}:", file=sys.stderr)
            for r in probe_bad[:10]:
                print(f"  {r['policy']} / {r['scenario']} / seed "
                      f"{r['seed']}", file=sys.stderr)
            raise FaultMatrixError(msg)
        print(f"# lease probe: 0 violations across "
              f"{sum(1 for r in rows if r['policy'] in consistent and r['scenario'] in safe)} "
              f"consistent-policy traced cells")
    print(f"\n# zero violations across {len(consistent)} consistent "
          f"policies"
          + (f"; inconsistent baseline flagged in {len(control)} cells"
             if control_expected or control else ""))
    return summary


if __name__ == "__main__":
    try:
        main()
    except FaultMatrixError:
        sys.exit(1)

"""Pallas TPU flash-decode kernel: one new token against a deep KV cache.

GQA-native: the query block is the GROUP of query heads sharing one KV
head — (grp, hd) lives in registers while the kernel streams the cache in
(block_s, hd) VMEM tiles with online softmax. HBM traffic = K + V read
once + (grp, hd) out; the XLA reference materializes (grp, S) scores and
(after GSPMD) broadcasts repeated KV in f32 (§Perf iteration 5b).

Grid = (B·Hkv, S/block_s), cache-block dim minormost so the (grp, hd)
accumulator persists in VMEM scratch across cache blocks. Invalid slots
(beyond ``cache_len``, e.g. unwritten ring-buffer entries) are masked via
a per-row length input.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, block_s: int, n_s_blocks: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                     # (grp, hd)
    k = k_ref[0].astype(jnp.float32)                     # (bs, hd)
    v = v_ref[0].astype(jnp.float32)                     # (bs, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = kpos < len_ref[0]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                               # (grp, bs)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(si == n_s_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 cache_len: jax.Array, *, block_s: int = 256,
                 interpret: bool = False) -> jax.Array:
    """q: (BHkv, grp, hd) grouped queries; caches: (BHkv, S, hd);
    cache_len: (BHkv,) int32 valid-slot counts. Returns (BHkv, grp, hd)."""
    bhkv, grp, hd = q.shape
    s = k_cache.shape[1]
    block_s = min(block_s, s)
    n_s = pl.cdiv(s, block_s)
    scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(_decode_kernel, scale=scale, block_s=block_s,
                               n_s_blocks=n_s)
    return pl.pallas_call(
        kernel,
        grid=(bhkv, n_s),
        in_specs=[
            pl.BlockSpec((1,), lambda b, j: (b,)),
            pl.BlockSpec((1, grp, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_s, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_s, hd), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, grp, hd), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bhkv, grp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((grp, 1), jnp.float32),
            pltpu.VMEM((grp, 1), jnp.float32),
            pltpu.VMEM((grp, hd), jnp.float32),
        ],
        interpret=interpret,
    )(cache_len.astype(jnp.int32), q, k_cache, v_cache)

import os
import sys

# tests run with PYTHONPATH=src, but make it robust when invoked otherwise
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

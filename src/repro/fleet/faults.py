"""Data-plane faults, composable with the control-plane nemesis.

:class:`FleetContext` extends the nemesis :class:`FaultContext` with the
fleet, so ONE scenario's window list can mix data-plane faults (below)
with any fault from :mod:`repro.faults.library` — ``CrashRestart`` the
Raft leader in the same window that ``CheckpointStorm`` floods commits,
and both fire off the shared deterministic schedule.

Victim scopes for data-plane faults (resolved at activation time, like
the nemesis's node scopes):

* ``chief`` — whoever is chief right now;
* ``workers:K`` — the K highest-index live non-chief workers;
* ``fraction:P`` — the ceil(P·n) highest-index live non-chief workers
  (highest-index so the min-index chief-succession line is perturbed by
  ``chief``/``ChiefKill`` deliberately, not as a side effect);
* ``all`` — every live worker;
* an explicit worker id (``w3``).
"""

from __future__ import annotations

import math
from typing import Optional

from ..faults.base import Fault, FaultContext, Scenario
from .sim import Fleet
from .worker import Worker


class FleetContext(FaultContext):
    def __init__(self, cluster, fleet: Fleet) -> None:
        super().__init__(cluster)
        self.fleet = fleet

    def live_fleet(self) -> list[Worker]:
        return [w for w in self.fleet.ordered_workers() if w.alive]

    def chief(self) -> Optional[Worker]:
        for w in self.fleet.ordered_workers():
            if w.alive and w.is_chief:
                return w
        return None

    def pick_fleet(self, scope: str) -> list[str]:
        live = self.live_fleet()
        if scope == "all":
            return [w.wid for w in live]
        if scope == "chief":
            chief = self.chief()
            return [chief.wid] if chief is not None else []
        rest = [w for w in live if not w.is_chief]
        if scope.startswith("workers:"):
            k = int(scope.split(":", 1)[1])
            return [w.wid for w in rest[-k:]] if k else []
        if scope.startswith("fraction:"):
            frac = float(scope.split(":", 1)[1])
            k = math.ceil(frac * len(self.fleet.workers))
            return [w.wid for w in rest[-k:]] if k else []
        if scope in self.fleet.workers:
            return [scope] if self.fleet.workers[scope].alive else []
        raise ValueError(f"unknown fleet victim scope {scope!r}")


class FleetScenario(Scenario):
    """A scenario whose windows may contain data-plane faults. Installed
    with the fleet in scope; the window scheduler is the nemesis's own."""

    def install(self, cluster) -> FaultContext:
        raise RuntimeError(
            "FleetScenario needs the fleet: use install_fleet(cluster, fleet)")

    def install_fleet(self, cluster, fleet: Fleet) -> FleetContext:
        ctx = FleetContext(cluster, fleet)
        self.ctx = ctx
        self._schedule(ctx)
        return ctx


# ------------------------------------------------------------ the faults
class WorkerCrash(Fault):
    """Crash the scope's workers; each restarts (re-registers, restores
    from the latest valid manifest) ``downtime`` later."""

    def __init__(self, scope: str = "fraction:0.3",
                 downtime: float = 0.5) -> None:
        self.scope = scope
        self.downtime = downtime
        self.name = f"worker_crash[{scope}]"

    def start(self, ctx: FleetContext) -> None:
        for wid in ctx.pick_fleet(self.scope):
            ctx.fleet.crash_worker(wid, downtime=self.downtime)


class WorkerStraggler(Fault):
    """Slow the scope's workers by ``factor`` for the window — the
    registry's straggler table should flag them, and unflag on stop."""

    def __init__(self, scope: str = "fraction:0.25",
                 factor: float = 4.0) -> None:
        self.scope = scope
        self.factor = factor
        self.name = f"worker_straggler[{scope},x{factor}]"
        self._victims: list[str] = []

    def start(self, ctx: FleetContext) -> None:
        self._victims = ctx.pick_fleet(self.scope)
        for wid in self._victims:
            ctx.fleet.workers[wid].slowdown = self.factor
            ctx.note(f"straggler {wid} x{self.factor}")

    def stop(self, ctx: FleetContext) -> None:
        for wid in self._victims:
            ctx.fleet.workers[wid].slowdown = 1.0
        self._victims = []


class ChiefKill(Fault):
    """Kill the chief. One-shot by default (retrying until a chief
    exists); with ``period`` it chases every newly elected chief, one
    strike per (worker, epoch) — the fleet's LeaderNemesis."""

    def __init__(self, downtime: float = 0.6,
                 period: Optional[float] = None) -> None:
        self.downtime = downtime
        self.period = period
        mode = "once" if period is None else f"p={period}"
        self.name = f"chief_kill[{mode}]"
        self._active = False
        self._struck: set = set()

    def start(self, ctx: FleetContext) -> None:
        self._active = True
        self._struck = set()
        self._tick(ctx)

    def _tick(self, ctx: FleetContext) -> None:
        if not self._active or not ctx.fleet.running:
            return
        chief = ctx.chief()
        if chief is not None and (chief.wid, chief.epoch) not in self._struck:
            self._struck.add((chief.wid, chief.epoch))
            ctx.note(f"chief_kill strikes {chief.wid} (epoch {chief.epoch})")
            ctx.fleet.crash_worker(chief.wid, downtime=self.downtime)
            if self.period is None:
                self._active = False
                return
        # one-shot mode keeps probing until it lands a strike
        ctx.loop.call_later(self.period if self.period is not None else 0.1,
                            lambda: self._tick(ctx))

    def stop(self, ctx: FleetContext) -> None:
        self._active = False


class CheckpointStorm(Fault):
    """Chief commits a manifest every ``every`` steps for the window —
    maximal write pressure on the coordinator, and the window in which a
    Raft-leader crash is most likely to catch a commit in flight."""

    def __init__(self, every: int = 1) -> None:
        self.every = every
        self.name = f"checkpoint_storm[every={every}]"

    def start(self, ctx: FleetContext) -> None:
        ctx.fleet.ckpt_override = self.every

    def stop(self, ctx: FleetContext) -> None:
        ctx.fleet.ckpt_override = None

"""Roofline benchmark: renders the §Roofline table from the dry-run JSON
rows (experiments/dryrun/*.json). With --compile (or when rows are
missing) it compiles the cells itself — slow on CPU; normally
``python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun``
produces the rows first."""

from __future__ import annotations

import glob
import json
import os

ROWS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "dryrun")


def load_rows(rows_dir: str = ROWS_DIR) -> list[dict]:
    rows = []
    for fn in sorted(glob.glob(os.path.join(rows_dir, "*.json"))):
        with open(fn) as f:
            rows.append(json.load(f))
    return rows


def run(quick: bool = False) -> list[dict]:
    rows = load_rows()
    out = []
    for r in rows:
        if r.get("status") == "skipped":
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "mesh": r["mesh"], "status": "skipped",
                        "compute_ms": "", "memory_ms": "",
                        "collective_ms": "", "dominant": "",
                        "roofline_pct": "", "hbm_gib_per_dev": ""})
            continue
        if r.get("status") != "ok":
            continue
        out.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok",
            "compute_ms": round(r["compute_s"] * 1e3, 2),
            "memory_ms": round(r["memory_s"] * 1e3, 2),
            "collective_ms": round(r["collective_s"] * 1e3, 2),
            "dominant": r["dominant"],
            "roofline_pct": round(100 * r["roofline_fraction"], 2),
            "hbm_gib_per_dev": round(
                (r["arg_bytes"] + r["temp_bytes"]) / 2**30, 2),
        })
    return out


def markdown_table(rows: list[dict]) -> str:
    """EXPERIMENTS.md §Roofline table."""
    hdr = ("| arch | shape | mesh | compute (ms) | memory (ms) | "
           "collective (ms) | dominant | useful-FLOPs | roofline | "
           "args+temp GiB/dev |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in sorted(load_rows(), key=lambda r: (r["arch"], r["shape"],
                                                r["mesh"])):
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"— skipped: sub-quadratic attention required — "
                         f"| | | | | | |")
            continue
        if r.get("status") != "ok":
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} "
            f"| {r['collective_s']*1e3:.1f} | {r['dominant']} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {100*r['roofline_fraction']:.2f}% "
            f"| {(r['arg_bytes']+r['temp_bytes'])/2**30:.1f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table(run()))

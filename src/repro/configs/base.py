"""Architecture + shape configuration for the model zoo."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | vlm | ssm | hybrid | audio
    n_layers: int
    d_model: int
    n_heads: int                 # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // n_heads

    # attention flavor
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: Optional[int] = None      # SWA window (tokens)
    rope_theta: float = 1e4

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_dense_residual: bool = False          # arctic: parallel dense FFN
    capacity_factor: float = 1.25

    # SSM / hybrid
    attn_free: bool = False                   # rwkv6
    hybrid_ssm: bool = False                  # hymba: parallel attn+SSM heads
    ssm_state: int = 0
    rwkv_head_dim: int = 64

    # modality frontend stub (vlm / audio): inputs are precomputed embeddings
    embedding_stub: bool = False

    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    # training knobs (perf-tunable; defaults overridden per arch/shape)
    grad_accum: int = 1
    remat: bool = True
    optimizer: str = "adamw"                  # adamw | adafactor
    param_dtype: str = "bfloat16"
    source: str = ""

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(1, self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def reduced(self) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=2,
            d_model=64,
            n_heads=0 if self.attn_free else 4,
            n_kv_heads=0 if self.attn_free else max(1, min(self.n_kv_heads, 2)),
            head_dim=0 if self.attn_free else 16,
            d_ff=128,
            vocab_size=256,
            n_experts=min(self.n_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            # drop-free capacity so prefill/decode agree exactly in tests
            capacity_factor=float(max(1, self.n_experts)),
            sliding_window=16 if self.sliding_window else None,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            rwkv_head_dim=16 if self.attn_free else self.rwkv_head_dim,
            grad_accum=1,
        )

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6·N·D)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        per_layer = 0
        if not self.attn_free:
            q = d * self.n_heads * self.hd
            kv = 2 * d * self.n_kv_heads * self.hd
            o = self.n_heads * self.hd * d
            per_layer += q + kv + o
        if self.attn_free:
            # rwkv6 time-mix: r,k,v,g,o (5 d*d) + decay/shift loras (small)
            per_layer += 5 * d * d + 2 * d * 64
            per_layer += 2 * d * f // 2 + d * f  # channel-mix approx
        elif self.hybrid_ssm:
            di = self.n_heads * self.hd
            per_layer += 2 * d * di + di * (2 * self.ssm_state + 2) + di * d
        if self.is_moe:
            experts = self.n_experts * 3 * d * f
            router = d * self.n_experts
            per_layer += experts + router
            if self.moe_dense_residual:
                per_layer += 3 * d * f
        elif not self.attn_free:
            per_layer += 3 * d * f              # swiglu
        per_layer += 2 * d                      # norms
        total = self.n_layers * per_layer + v * d + 2 * d
        if not self.tie_embeddings:
            total += v * d
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        inactive = self.n_layers * (self.n_experts - self.experts_per_token) \
            * 3 * d * f
        return self.param_count() - inactive


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                    # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524288, 1)

ALL_SHAPES = [TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K]


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """long_500k needs sub-quadratic attention / bounded state (DESIGN.md
    §Arch-applicability)."""
    if shape.name == "long_500k":
        sub_quadratic = arch.attn_free or arch.hybrid_ssm or \
            (arch.sliding_window is not None)
        if not sub_quadratic:
            return False, ("pure full-attention arch: 500k-context decode "
                           "requires sub-quadratic attention (skip noted in "
                           "DESIGN.md)")
    return True, ""

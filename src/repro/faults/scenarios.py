"""Named scenario registry + random scenario generator.

A *scenario* is a reusable fault schedule; the registry maps names to
factories (fresh ``Fault`` instances per run, since faults carry undo
state). ``expect_safe`` classifies the schedule:

* safe — inside the fault model every consistent policy claims to
  tolerate (crashes, any partition topology, message chaos, honest clock
  skew/drift, I/O slowdown). The fault matrix asserts **zero**
  linearizability violations here.
* unsafe — exceeds the model (lying clocks breaching the §4.3 bound,
  disk loss breaking vote persistence). Violations are expected findings
  that prove the checker bites, not failures.

Adding a scenario: write a factory returning ``[Window(...), ...]`` and
decorate it with ``@scenario(name, ...)``; it then shows up in the
matrix, the conformance tests, and ``benchmarks/fault_matrix.py``
automatically. Window times are relative to workload start; the standard
matrix run lasts ~1.2 s of simulated time.
"""

from __future__ import annotations

import random
from typing import Callable, Optional

from .base import Scenario, Window
from .library import (ClockSkew, CorruptFault, CrashRestart, DiskLossRejoin,
                      FlappingLink, IoSlowdown, IsolateLeader, LeaderNemesis,
                      MajorityMinority, MembershipChaos, MessageChaos,
                      OneWayLink, PartialPartition, SlowNode)

#: name -> scenario factory; call ``build_scenario(name)`` for a run-ready
#: instance. Iteration order is the canonical matrix order.
SCENARIOS: dict[str, Callable[[], Scenario]] = {}


def scenario(name: str, expect_safe: bool = True, description: str = "",
             raft_overrides: Optional[dict] = None,
             meta: Optional[dict] = None):
    """Register a window-list factory as a named scenario.
    ``raft_overrides`` are RaftParams kwargs the scenario needs for its
    ``expect_safe`` classification (e.g. checksums for corruption)."""

    def deco(factory: Callable[[], list[Window]]):
        def build() -> Scenario:
            return Scenario(name, factory(), expect_safe=expect_safe,
                            description=description,
                            raft_overrides=raft_overrides, meta=meta)

        build.scenario_name = name
        build.expect_safe = expect_safe
        build.description = description
        build.raft_overrides = dict(raft_overrides or {})
        SCENARIOS[name] = build
        return build

    return deco


def build_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]()
    except KeyError:
        raise ValueError(
            f"unknown scenario {name!r}; registered: {sorted(SCENARIOS)}"
        ) from None


def safe_scenario_names() -> list[str]:
    return [n for n, f in SCENARIOS.items() if f.expect_safe]


def unsafe_scenario_names() -> list[str]:
    return [n for n, f in SCENARIOS.items() if not f.expect_safe]


# ------------------------------------------------------------ the catalogue
@scenario("leader_crash_restart",
          description="leader crashes at 0.3s, returns with disk at 0.7s")
def _leader_crash_restart() -> list[Window]:
    return [Window(CrashRestart("leader", downtime=0.4), at=0.3)]


@scenario("leader_nemesis",
          description="crash-restart nemesis chasing every new leader")
def _leader_nemesis() -> list[Window]:
    return [Window(LeaderNemesis(period=0.45, downtime=0.25), at=0.2,
                   until=1.1)]


@scenario("asym_partition_leader_deaf",
          description="one-way cut: leader sends but hears nothing")
def _asym_leader_deaf() -> list[Window]:
    return [Window(IsolateLeader("in"), at=0.3, until=0.8)]


@scenario("asym_partition_leader_mute",
          description="one-way cut: leader hears but cannot send")
def _asym_leader_mute() -> list[Window]:
    return [Window(IsolateLeader("out"), at=0.3, until=0.8)]


@scenario("majority_minority",
          description="leader trapped in a minority side for 0.6s")
def _majority_minority() -> list[Window]:
    return [Window(MajorityMinority(leader_in_minority=True), at=0.25,
                   until=0.85)]


@scenario("partial_partition",
          description="single follower-follower link cut; both see the rest")
def _partial_partition() -> list[Window]:
    return [Window(PartialPartition(), at=0.2, until=0.9)]


@scenario("oneway_flaky_link",
          description="one directed follower link dead, reverse alive")
def _oneway_link() -> list[Window]:
    return [Window(OneWayLink(), at=0.2, until=0.9)]


@scenario("clock_skew_minority",
          description="honest +80ms skew on a follower minority (beyond Δ "
                      "assumptions, bounds stay truthful)")
def _clock_skew_minority() -> list[Window]:
    return [Window(ClockSkew(skew=0.08, scope="minority"), at=0.2,
                   until=1.0)]


@scenario("clock_drift_all",
          description="honest 50ms/s drift on every node")
def _clock_drift_all() -> list[Window]:
    return [Window(ClockSkew(skew=0.0, drift_rate=0.05, scope="all"),
                   at=0.2, until=1.0)]


@scenario("delay_spike",
          description="+25ms one-way latency with 15ms jitter, all links")
def _delay_spike() -> list[Window]:
    return [Window(MessageChaos(extra_delay=0.025, jitter=0.015,
                                label="delay"), at=0.3, until=0.8)]


@scenario("dup_reorder",
          description="30% duplication + 10ms reorder jitter, all links")
def _dup_reorder() -> list[Window]:
    return [Window(MessageChaos(dup_prob=0.3, jitter=0.01,
                                label="dup+reorder"), at=0.15, until=1.0)]


@scenario("flaky_network",
          description="20% iid message loss on every link")
def _flaky_network() -> list[Window]:
    return [Window(MessageChaos(drop_prob=0.2, label="loss"), at=0.2,
                   until=0.9)]


@scenario("io_slowdown_leader",
          description="+300µs per-message I/O service time on the leader")
def _io_slowdown() -> list[Window]:
    return [Window(IoSlowdown(300e-6, scope="leader"), at=0.3, until=0.8)]


@scenario("combo_chaos",
          description="delay spike over a partial partition, then a leader "
                      "crash while messages duplicate")
def _combo_chaos() -> list[Window]:
    return [
        Window(PartialPartition(), at=0.15, until=0.7),
        Window(MessageChaos(extra_delay=0.01, jitter=0.01, label="delay"),
               at=0.25, until=0.9),
        Window(MessageChaos(dup_prob=0.2, label="dup"), at=0.4, until=1.0),
        Window(CrashRestart("leader", downtime=0.3), at=0.5),
    ]


# --------------------------------------------------- gray-failure tier
@scenario("slow_follower",
          description="one follower gray-degrades: +500µs I/O service plus "
                      "~100ms straggle on everything it sends — alive to "
                      "failure detectors, useless to the quorum")
def _slow_follower() -> list[Window]:
    return [Window(SlowNode("minority", extra_io=500e-6, send_delay=0.1,
                            send_jitter=0.05), at=0.2, until=0.9)]


@scenario("slow_leader",
          description="the leader itself straggles: heartbeats and "
                      "replication limp out ~60ms late — the CheckQuorum "
                      "borderline case")
def _slow_leader() -> list[Window]:
    return [Window(SlowNode("leader", extra_io=300e-6, send_delay=0.06,
                            send_jitter=0.03), at=0.3, until=0.8)]


@scenario("flapping_node",
          description="first follower's inbound links flap on a 450ms-down/"
                      "250ms-up duty cycle (down > election timeout): it "
                      "repeatedly goes deaf, times out, and — without "
                      "PreVote — its term-bumping candidacies evict a "
                      "healthy leader every flap",
          meta={"flap_down": 0.45, "flap_up": 0.25})
def _flapping_node() -> list[Window]:
    return [Window(FlappingLink("followers", direction="in",
                                up=0.25, down=0.45), at=0.2, until=1.2)]


@scenario("flapping_outbound",
          description="first follower's outbound links flap: its votes and "
                      "acks vanish intermittently while it still hears the "
                      "leader (no election pressure, replication staggers)",
          meta={"flap_down": 0.15, "flap_up": 0.2})
def _flapping_outbound() -> list[Window]:
    return [Window(FlappingLink("followers", direction="out",
                                up=0.2, down=0.15), at=0.2, until=1.0)]


@scenario("gray_combo",
          description="slow follower + flapping deaf follower + global "
                      "delay spike: the full gray-failure gauntlet")
def _gray_combo() -> list[Window]:
    return [
        Window(SlowNode("minority", extra_io=300e-6, send_delay=0.08,
                        send_jitter=0.04), at=0.15, until=0.9),
        Window(FlappingLink("followers", direction="in",
                            up=0.25, down=0.45), at=0.3, until=1.2),
        Window(MessageChaos(extra_delay=0.01, jitter=0.01, label="delay"),
               at=0.4, until=0.8),
    ]


# --------------------------------------------------- corruption tier
@scenario("corrupt_entries_checked",
          description="8% of AppendEntries mutated in flight (payloads, "
                      "prev_index/term, commit_index); end-to-end checksums "
                      "detect and drop every corrupted message",
          raft_overrides={"entry_checksums": True})
def _corrupt_entries_checked() -> list[Window]:
    return [Window(CorruptFault(prob=0.08, seed=0xBADC0DE), at=0.2,
                   until=0.9)]


@scenario("corrupt_storm_checked",
          description="25% corruption rate plus a leader crash mid-storm; "
                      "checksums must still hold the line",
          raft_overrides={"entry_checksums": True})
def _corrupt_storm_checked() -> list[Window]:
    return [
        Window(CorruptFault(prob=0.25, seed=0xC0FFEE), at=0.15, until=1.0),
        Window(CrashRestart("leader", downtime=0.3), at=0.5),
    ]


@scenario("corrupt_entries_unchecked", expect_safe=False,
          description="the corrupt_storm schedule with checksums OFF: "
                      "corrupted entries replicate, a follower with a "
                      "poisoned log takes over after the crash, and the "
                      "divergence becomes client-visible — violations here "
                      "are the checker's positive control")
def _corrupt_entries_unchecked() -> list[Window]:
    return [
        Window(CorruptFault(prob=0.25, seed=0xC0FFEE), at=0.15, until=1.0),
        Window(CrashRestart("leader", downtime=0.3), at=0.5),
    ]


# ------------------------------------------------------ membership chaos
@scenario("membership_churn",
          description="scheduled add-learner/promote/remove churn through "
                      "change_membership (paper §4.4)")
def _membership_churn() -> list[Window]:
    return [Window(MembershipChaos(period=0.2, adds=2, removes=2), at=0.2,
                   until=1.1)]


@scenario("membership_churn_crash",
          description="membership churn with the leader crash-restarting "
                      "mid-schedule")
def _membership_churn_crash() -> list[Window]:
    return [
        Window(MembershipChaos(period=0.2, adds=2, removes=1), at=0.2,
               until=1.1),
        Window(CrashRestart("leader", downtime=0.3), at=0.55),
    ]


@scenario("membership_churn_partition",
          description="membership churn while a follower-follower link is "
                      "cut, then a majority/minority split")
def _membership_churn_partition() -> list[Window]:
    return [
        Window(MembershipChaos(period=0.25, adds=1, removes=1), at=0.15,
               until=1.1),
        Window(PartialPartition(), at=0.3, until=0.7),
        Window(MajorityMinority(leader_in_minority=True), at=0.8,
               until=1.1),
    ]


@scenario("disk_loss_safe",
          description="a follower loses its disk but rejoins as a learner "
                      "(demote-while-down, catch up, auto-promote), then "
                      "the leader crashes: the safe default rejoin path")
def _disk_loss_safe() -> list[Window]:
    return [
        Window(DiskLossRejoin("minority", downtime=0.2), at=0.25),
        Window(CrashRestart("leader", downtime=0.3), at=0.55),
    ]


# -------------------------------------------------- beyond the fault model
@scenario("clock_lie_leader", expect_safe=False,
          description="leader's clock claims tight bounds while 10s slow: "
                      "its lease never looks expired (§4.3 breach)")
def _clock_lie() -> list[Window]:
    return [
        Window(ClockSkew(skew=-10.0, scope="leader", lie=True), at=0.2),
        Window(MajorityMinority(leader_in_minority=True), at=0.3,
               until=1.0),
    ]


@scenario("disk_loss", expect_safe=False,
          description="a follower loses its disk across a restart and "
                      "rejoins as a FULL VOTER, then the leader crashes: "
                      "vote persistence is broken (the safe default is "
                      "disk_loss_safe: rejoin as learner, then promote)")
def _disk_loss() -> list[Window]:
    return [
        Window(CrashRestart("minority", downtime=0.2, wipe_disk=True),
               at=0.25),
        Window(CrashRestart("leader", downtime=0.3), at=0.55),
    ]


# ------------------------------------------------------ random composition
def random_scenario(seed: int, duration: float = 1.2) -> Scenario:
    """Compose 1-3 random faults from the *safe* library into a scenario —
    deterministic in ``seed``. Used by the property tests to fuzz the
    schedule space beyond the named catalogue."""
    rng = random.Random(seed)
    pool: list[Callable[[random.Random], "object"]] = [
        lambda r: CrashRestart("leader", downtime=r.uniform(0.15, 0.45)),
        lambda r: CrashRestart("minority", downtime=r.uniform(0.15, 0.45)),
        lambda r: IsolateLeader(r.choice(["both", "in", "out"])),
        lambda r: MajorityMinority(leader_in_minority=r.random() < 0.5),
        lambda r: PartialPartition(),
        lambda r: OneWayLink(),
        lambda r: ClockSkew(skew=r.uniform(-0.1, 0.1),
                            drift_rate=r.uniform(0.0, 0.05),
                            scope=r.choice(["leader", "minority", "all"])),
        lambda r: MessageChaos(extra_delay=r.uniform(0.0, 0.02),
                               jitter=r.uniform(0.0, 0.015),
                               drop_prob=r.uniform(0.0, 0.25),
                               dup_prob=r.uniform(0.0, 0.25),
                               label="random"),
        lambda r: IoSlowdown(r.uniform(50e-6, 400e-6),
                             scope=r.choice(["leader", "all"])),
        lambda r: LeaderNemesis(period=r.uniform(0.35, 0.6),
                                downtime=r.uniform(0.15, 0.3)),
    ]
    windows = []
    for _ in range(rng.randint(1, 3)):
        fault = rng.choice(pool)(rng)
        at = rng.uniform(0.1, 0.5 * duration)
        until = min(duration - 0.05, at + rng.uniform(0.2, 0.6 * duration))
        windows.append(Window(fault, at=at, until=until))
    return Scenario(f"random_{seed}", windows, expect_safe=True,
                    description=f"random composition (seed {seed})")


def random_membership_scenario(seed: int, duration: float = 1.2) -> Scenario:
    """Random membership-churn schedule: one churn window (add/promote/
    remove through ``change_membership``, or a safe wipe-then-learner
    rejoin) overlapped with 0-2 faults from the safe library —
    deterministic in ``seed``. Exercises learner promotion mid-partition,
    remove-then-crash, and wipe-then-rejoin interleavings the named
    catalogue can't enumerate."""
    rng = random.Random(seed ^ 0x5EED)
    windows = []
    if rng.random() < 0.7:
        churn = MembershipChaos(period=rng.uniform(0.15, 0.35),
                                adds=rng.randint(1, 2),
                                removes=rng.randint(0, 2),
                                decommission=rng.random() < 0.7,
                                victim=rng.choice(["low", "high"]))
    else:
        churn = DiskLossRejoin("minority",
                               downtime=rng.uniform(0.15, 0.35))
    windows.append(Window(churn, at=rng.uniform(0.1, 0.3),
                          until=duration - 0.1))
    pool = [
        lambda r: CrashRestart("leader", downtime=r.uniform(0.15, 0.4)),
        lambda r: PartialPartition(),
        lambda r: MajorityMinority(leader_in_minority=r.random() < 0.5),
        lambda r: IsolateLeader(r.choice(["both", "in", "out"])),
        lambda r: MessageChaos(extra_delay=r.uniform(0.0, 0.015),
                               jitter=r.uniform(0.0, 0.01),
                               drop_prob=r.uniform(0.0, 0.15),
                               label="random"),
    ]
    for _ in range(rng.randint(0, 2)):
        fault = rng.choice(pool)(rng)
        at = rng.uniform(0.25, 0.6 * duration)
        until = min(duration - 0.05, at + rng.uniform(0.2, 0.5 * duration))
        windows.append(Window(fault, at=at, until=until))
    return Scenario(f"random_membership_{seed}", windows, expect_safe=True,
                    description=f"random membership churn (seed {seed})")


def random_gray_scenario(seed: int, duration: float = 1.2) -> Scenario:
    """Random gray-failure schedule: exactly one :class:`FlappingLink`
    (random duty cycle and direction) overlapped with 0-2 degradations
    (slow node, delay chaos, I/O slowdown) — deterministic in ``seed``.
    Crash- and partition-free, so voting-quorum connectivity persists
    throughout: the schedule space over which the PreVote/CheckQuorum
    term-inflation and single-lease-holder properties are asserted.

    Separate draw path (note the salt): adding this generator leaves
    ``random_scenario`` / ``random_membership_scenario`` sequences for
    every existing seed untouched."""
    rng = random.Random(seed ^ 0x6EA7)
    # down phases straddle the matrix election timeout (0.3-0.4s): some
    # flaps starve the victim long enough to campaign, some don't
    down = rng.uniform(0.25, 0.55)
    up = rng.uniform(0.15, 0.35)
    flap = FlappingLink("followers",
                        direction=rng.choice(["in", "out", "pair"]),
                        up=up, down=down)
    windows = [Window(flap, at=rng.uniform(0.1, 0.3),
                      until=duration - 0.1)]
    pool = [
        lambda r: SlowNode("minority", extra_io=r.uniform(100e-6, 500e-6),
                           send_delay=r.uniform(0.02, 0.1),
                           send_jitter=r.uniform(0.0, 0.05)),
        lambda r: MessageChaos(extra_delay=r.uniform(0.0, 0.015),
                               jitter=r.uniform(0.0, 0.01), label="gray"),
        lambda r: IoSlowdown(r.uniform(50e-6, 300e-6), scope="all"),
    ]
    for _ in range(rng.randint(0, 2)):
        fault = rng.choice(pool)(rng)
        at = rng.uniform(0.15, 0.5 * duration)
        until = min(duration - 0.05, at + rng.uniform(0.2, 0.6 * duration))
        windows.append(Window(fault, at=at, until=until))
    return Scenario(f"random_gray_{seed}", windows, expect_safe=True,
                    description=f"random gray-failure schedule (seed {seed})",
                    meta={"flap_down": down, "flap_up": up})

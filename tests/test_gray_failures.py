"""Gray-failure resilience: PreVote, CheckQuorum, adaptive replication
backoff — unit behavior plus the property tests over random gray
schedules (term inflation bounded per flap window; a lease is never held
by two nodes at once)."""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fixed-example fallback
    from _hypothesis_stub import given, settings, st

from repro.core import (RaftParams, ReadMode, SimParams, build_cluster,
                        check_linearizability, run_workload)
from repro.faults import FlappingLink, random_gray_scenario

ET = 0.3


def make(**kw):
    raft = RaftParams(read_mode=ReadMode.LEASEGUARD, election_timeout=ET,
                      election_jitter=0.1, heartbeat_interval=0.03,
                      lease_duration=0.6, rpc_timeout=0.15, **kw)
    c = build_cluster(raft, SimParams(seed=5))
    return c, c.wait_for_leader()


def settle(c, dt):
    c.loop.run_until(c.loop.now + dt)


def deafen(c, victim):
    """Cut every inbound link of ``victim`` (it can still send)."""
    for other in c.nodes.values():
        if other is not victim:
            c.net.partition_oneway(other.id, victim.id)


# ------------------------------------------------------------------ PreVote
def test_deaf_follower_storms_terms_without_prevote():
    """Baseline disruption: a follower that hears nothing but can still
    send campaigns with real term bumps, evicting the healthy leader on
    every election timeout."""
    c, ldr = make()
    t0 = ldr.term
    victim = next(n for n in c.nodes.values() if n is not ldr)
    deafen(c, victim)
    settle(c, 4 * ET)
    assert victim.term > t0                   # terms inflated
    assert ldr.leader_evictions >= 1          # healthy leader deposed
    assert ldr.healthy_evictions >= 1


def test_prevote_blocks_deaf_follower_disruption():
    """With PreVote the victim's trial ballots go unanswered (replies are
    cut inbound), so it never bumps its term and the healthy leader is
    never evicted."""
    c, ldr = make(prevote=True)
    t0 = ldr.term
    victim = next(n for n in c.nodes.values() if n is not ldr)
    deafen(c, victim)
    settle(c, 6 * ET)
    assert victim.term == t0                  # no term inflation
    assert victim.prevote_rounds >= 1         # it did try
    assert ldr.is_leader() and ldr.leader_evictions == 0


def test_prevote_still_elects_after_real_leader_death():
    """PreVote must not block legitimate elections: followers grant the
    trial ballot once the leader is silent past an election timeout."""
    c, ldr = make(prevote=True)
    ldr.crash()
    settle(c, 8 * ET)
    new = [n for n in c.nodes.values() if n.is_leader()]
    assert len(new) == 1 and new[0] is not ldr


def test_prevote_denied_while_leader_is_live():
    """Leader stickiness: a node that heard the leader within an election
    timeout refuses the trial ballot even for an up-to-date log."""
    from repro.core.raft import PreVoteRequest
    c, ldr = make(prevote=True)
    f = next(n for n in c.nodes.values() if n is not ldr)
    settle(c, 0.1)                            # fresh heartbeat received
    reply = f._handle_prevote(99, PreVoteRequest(
        f.term + 1, 99, f.last_log_index, f.log[f.last_log_index].term))
    assert not reply.granted
    assert f.term == ldr.term                 # trial ballot bumped nothing


# -------------------------------------------------------------- CheckQuorum
def test_check_quorum_steps_down_partitioned_leader():
    """A leader that stops hearing acks relinquishes leadership (and its
    lease) within ~an election timeout instead of serving a doomed lease
    window."""
    c, ldr = make(check_quorum=True)
    deafen(c, ldr)                            # leader sends, hears nothing
    settle(c, 4 * ET)
    assert not ldr.is_leader()
    assert ldr.quorum_step_downs >= 1
    # voluntary step-down with no quorum is not a *healthy* eviction
    assert ldr.healthy_evictions == 0


def test_leader_without_check_quorum_keeps_serving():
    """Contrast: with the flag off the deaf leader stays 'leader' in its
    own eyes for the full run (nothing forces it out — its own term never
    moves and it hears no higher term)."""
    c, ldr = make()
    deafen(c, ldr)
    settle(c, 4 * ET)
    assert ldr.state == "leader"
    assert ldr.quorum_step_downs == 0


# ----------------------------------------------------------------- backoff
def test_backoff_reduces_retry_traffic_to_dead_peer():
    """Capped exponential backoff sends measurably fewer RPCs at a dead
    peer than the fixed rpc_timeout hot loop, without giving up on it."""
    sent = {}
    for flag in (False, True):
        c, ldr = make(replication_backoff=flag)
        victim = next(n for n in c.nodes.values() if n is not ldr)
        before = c.net.messages_sent
        victim.crash()
        settle(c, 3.0)
        sent[flag] = c.net.messages_sent - before
        if flag:
            assert ldr._backoff_fails.get(victim.id, 0) >= 3
    assert sent[True] < sent[False]


def test_backoff_state_clears_on_peer_recovery():
    c, ldr = make(replication_backoff=True)
    victim = next(n for n in c.nodes.values() if n is not ldr)
    victim.crash()
    settle(c, 1.5)
    assert ldr._backoff_fails.get(victim.id, 0) >= 1
    victim.restart()
    settle(c, 2.0)
    assert victim.id not in ldr._backoff_fails   # first ack reset it
    assert victim.data == ldr.data               # and it caught up


# ------------------------------------------------- gray schedule properties
def _gray_run(seed: int):
    """One random gray schedule under the full resilience tier, with an
    omniscient lease-overlap sampler riding on the loop."""
    sc = random_gray_scenario(seed)
    raft = RaftParams(read_mode=ReadMode.LEASEGUARD, election_timeout=ET,
                      election_jitter=0.1, heartbeat_interval=0.03,
                      lease_duration=0.6, rpc_timeout=0.15,
                      prevote=True, check_quorum=True,
                      replication_backoff=True)
    sim = SimParams(seed=seed % 97, sim_duration=1.2, interarrival=3e-3)
    overlaps = []

    def script(cluster):
        sc.install(cluster)

        def sample():
            holders = [n.id for n in cluster.nodes.values()
                       if n.alive and n.policy.holds_lease()]
            if len(holders) > 1:
                overlaps.append((cluster.loop.now, holders))
            cluster.loop.call_later(0.01, sample)

        cluster.loop.call_later(0.01, sample)

    res = run_workload(raft, sim, fault_script=script, check=False,
                       settle_time=1.5)
    flaps = sum(w.fault.flaps for w in sc.windows
                if isinstance(w.fault, FlappingLink))
    return res, flaps, overlaps


@given(seed=st.integers(0, 10_000))
@settings(max_examples=6, deadline=None)
def test_random_gray_schedule_bounds_term_inflation(seed):
    """Over any random gray schedule (flapping + slow nodes + delay; a
    voting quorum stays connected throughout), PreVote + CheckQuorum hold
    term inflation to at most one term per flap window."""
    res, flaps, _ = _gray_run(seed)
    inflation = res.raft_stats["max_term"] - 1
    assert inflation <= max(1, flaps), \
        f"term inflation {inflation} > flap windows {flaps} (seed {seed})"
    assert res.raft_stats["healthy_evictions"] <= flaps
    assert check_linearizability(res.history) >= 0


@given(seed=st.integers(0, 10_000))
@settings(max_examples=6, deadline=None)
def test_random_gray_schedule_never_double_leases(seed):
    """Across any gray schedule, two nodes never hold a serving lease at
    the same instant (sampled omnisciently every 10 ms of simulated
    time)."""
    _, _, overlaps = _gray_run(seed + 424242)
    assert not overlaps, f"concurrent lease holders: {overlaps[:3]}"

"""Build a replica set, run a workload, gather stats (paper run_with_params).

This is the entry point used by tests, benchmarks, and examples. Given
(RaftParams, SimParams, seed) it is fully deterministic.

Warm-start fast path
--------------------

Sweeps (``benchmarks/fault_matrix.py``, ``benchmarks/simperf.py``) run the
same (RaftParams, policy) cell over many seeds, and every cold run pays
for the same cluster boot + leader election before the workload starts.
:meth:`Cluster.snapshot` captures a post-election cluster as plain state
(logs with shared entries preserved, applied KV state, terms/votes, the
elected leader) and :meth:`ClusterSnapshot.restore` rehydrates it onto a
fresh event loop, re-asserting the leader's leadership at its snapshot
term and re-keying every PRNG stream with the target seed so each restored
run diverges per seed. ``run_workload(warm_start=True)`` amortizes one
snapshot per (RaftParams, SimParams-minus-seed) across all seeds.

A warm run is NOT bit-identical to the cold run of the same seed (the
boot phase is shared, and PRNG streams are re-keyed); it is deterministic
— the same (params, seed, warm_start=True) always replays identically —
and semantically equivalent: a settled cluster with an established leader
serving the same workload distribution. Cold runs are byte-for-byte
unaffected by the fast path.
"""

from __future__ import annotations

import copy
from dataclasses import astuple, dataclass, field, replace
from typing import Callable, Optional

from .checker import check_linearizability
from .client import ClientLogEntry, Directory, Workload
from .clock import BoundedClock
from .network import NetParams, Network
from .params import RaftParams, SimParams
from .prob import PRNG
from .raft import Node
from .simulate import EventLoop
from ..obs.metrics import Metrics
from ..obs.trace import Tracer


@dataclass
class Cluster:
    loop: EventLoop
    net: Network
    nodes: dict[int, Node]
    directory: Directory
    prng: PRNG

    def leader(self) -> Optional[Node]:
        lid = self.directory.leader_id
        return self.nodes.get(lid) if lid is not None else None

    def wait_for_leader(self, max_time: float = 10.0) -> Node:
        """Run the loop until some node is leader.

        Event-driven: blocks on :attr:`Directory.announcements` instead of
        polling every 10 ms, then aligns the clock to the historical 10 ms
        polling boundary — so the workload start time (and every PRNG draw
        after it) is bit-identical to the old polling loop."""
        loop = self.loop
        deadline = loop.now + max_time
        boundary = loop.now
        for n in self.nodes.values():       # warm restores: already led
            if n.is_leader():
                return n
        while loop.now < deadline:
            gen = self.directory.announcements
            while self.directory.announcements == gen and not loop._stopped:
                t = loop._next_time()
                if t is None or t > deadline:
                    # nothing left that could elect anyone before deadline
                    loop.run_until(deadline)
                    raise RuntimeError("no leader elected")
                loop._step()
            # replicate the old polling loop's accumulated 10 ms grid so
            # loop.now lands exactly where run_until(now + 0.01) would
            while boundary < loop.now:
                boundary += 0.01
            loop.run_until(boundary)
            for n in self.nodes.values():
                if n.is_leader():
                    return n
        raise RuntimeError("no leader elected")

    def spawn_node(self, node_id: int, raft: RaftParams,
                   max_clock_error: float = 50e-6,
                   learner: bool = True) -> Node:
        """Create a fresh node (elastic scaling; it joins the replica set
        once a leader appends the CONFIG entry that includes it). By
        default the newcomer considers itself a non-voting learner until
        a replicated CONFIG says otherwise — so it can never elect itself
        leader of a one-node 'cluster' before it is added."""
        from .clock import BoundedClock
        clock = BoundedClock(self.loop, self.prng.fork(600 + node_id),
                             max_clock_error)
        if learner:
            peers, learners = [], [node_id]
        else:
            peers, learners = [node_id], []
        node = Node(node_id, self.loop, self.net, clock,
                    self.prng.fork(700 + node_id), raft,
                    peers,            # adopts the real config from the log
                    on_leader=self.directory.on_leader, learners=learners)
        self.nodes[node_id] = node
        return node

    def snapshot(self) -> "ClusterSnapshot":
        """Capture the cluster's replicated + applied state for warm
        restarts. Meant to be taken at a quiescent point (post-election,
        pre-workload): in-flight RPCs and parked timers are deliberately
        NOT captured — :meth:`ClusterSnapshot.restore` regenerates the
        leader's replication machinery instead."""
        return ClusterSnapshot(self)


class ClusterSnapshot:
    """Plain-state capture of a booted cluster (see module docstring).

    ``restore(seed)`` rehydrates onto a fresh event loop: followers come
    back with their logs/terms/applied state, the snapshot leader
    re-asserts leadership at its snapshot term through the normal
    ``_become_leader`` path (fresh no-op, fresh replication tasks, fresh
    policy state — policy state is process-volatile by design), and every
    PRNG stream is re-keyed with ``seed`` for per-seed divergence."""

    def __init__(self, cluster: Cluster) -> None:
        self.now = cluster.loop.now
        self.net_params = replace(cluster.net.params)
        leader = None
        for nid, n in sorted(cluster.nodes.items()):
            if n.is_leader():
                leader = nid
                break
        self.leader_id = leader
        # one memo across all nodes: LogEntry objects shared between
        # replicas in the sim stay shared in the snapshot (and in every
        # restore), which the omniscient checker relies on
        memo: dict = {}
        self.raft = cluster.nodes[next(iter(cluster.nodes))].p
        self.nodes: dict[int, dict] = {}
        for nid, n in sorted(cluster.nodes.items()):
            self.nodes[nid] = {
                "term": n.term,
                "voted_for": n.voted_for,
                "log": copy.deepcopy(n.log, memo),
                "commit_index": n.commit_index,
                "last_applied": n.last_applied,
                "data": copy.deepcopy(n.data, memo),
                "config": set(n.config),
                "learners": set(n.learners),
                "leader_hint": n.leader_hint,
            }

    def restore(self, seed: int) -> Cluster:
        loop = EventLoop()
        loop.now = self.now
        # re-key every stream: same snapshot + same seed -> identical run,
        # different seeds -> divergent latencies/workload/clock draws
        root = PRNG((seed * 0x9E3779B97F4A7C15 + 0xB007) % 2**63)
        net = Network(loop, root.fork(101), replace(self.net_params))
        directory = Directory()
        ids = sorted(self.nodes)
        memo: dict = {}
        nodes: dict[int, Node] = {}
        for nid in ids:
            st = self.nodes[nid]
            clock = BoundedClock(loop, root.fork(200 + nid),
                                 self.raft.max_clock_error)
            node = Node(nid, loop, net, clock, root.fork(300 + nid),
                        self.raft, ids, on_leader=directory.on_leader)
            node.term = st["term"]
            node.voted_for = st["voted_for"]
            node.log = copy.deepcopy(st["log"], memo)
            node.commit_index = st["commit_index"]
            node.last_applied = st["last_applied"]
            node.data = copy.deepcopy(st["data"], memo)
            node.config = set(st["config"])
            node.learners = set(st["learners"])
            node.leader_hint = st["leader_hint"]
            nodes[nid] = node
        cluster = Cluster(loop, net, nodes, directory, root)
        if self.leader_id is not None:
            leader = nodes[self.leader_id]
            # re-assert leadership at the snapshot term: appends a fresh
            # no-op, spawns replication + policy maintenance, announces
            leader._become_leader()
            noop_index = leader.last_log_index
            # settle until the no-op applies on the leader (lease live),
            # mirroring what the tail of a cold boot provides
            deadline = loop.now + 10 * self.raft.heartbeat_interval
            while leader.is_leader() and leader.last_applied < noop_index:
                t = loop._next_time()
                if t is None or t > deadline:
                    break
                loop._step()
        if cluster.leader() is None or not cluster.leader().is_leader():
            cluster.wait_for_leader()   # contested snapshot: fall back
        return cluster


#: fixed seed for the shared boot phase of every warm-started cell
WARM_BOOT_SEED = 0xB007

_WARM_CACHE: dict[tuple, ClusterSnapshot] = {}
_WARM_CACHE_MAX = 64


def _warm_key(raft: RaftParams, sim: SimParams) -> tuple:
    return (astuple(raft), astuple(replace(sim, seed=0)))


def warm_cluster(raft: RaftParams, sim: SimParams) -> Cluster:
    """A post-election cluster for ``sim.seed``, amortizing one boot +
    election per (RaftParams, SimParams-minus-seed) across all seeds."""
    key = _warm_key(raft, sim)
    snap = _WARM_CACHE.get(key)
    if snap is None:
        boot = build_cluster(raft, replace(sim, seed=WARM_BOOT_SEED))
        boot.wait_for_leader()
        snap = boot.snapshot()
        if len(_WARM_CACHE) >= _WARM_CACHE_MAX:
            _WARM_CACHE.pop(next(iter(_WARM_CACHE)))
        _WARM_CACHE[key] = snap
    return snap.restore(sim.seed)


def clear_warm_cache() -> None:
    _WARM_CACHE.clear()


def build_cluster(raft: RaftParams, sim: SimParams,
                  clock_faults: Optional[dict[int, float]] = None) -> Cluster:
    loop = EventLoop()
    prng = PRNG(sim.seed)
    net = Network(loop, prng.fork(101), NetParams(
        one_way_latency_mean=sim.one_way_latency_mean,
        one_way_latency_variance=sim.one_way_latency_variance,
        io_service_time=sim.io_service_time,
        rpc_timeout=raft.rpc_timeout,
    ))
    directory = Directory()
    ids = list(range(raft.n_nodes))
    nodes = {}
    for i in ids:
        fault = (clock_faults or {}).get(i, 0.0)
        clock = BoundedClock(loop, prng.fork(200 + i), raft.max_clock_error,
                             faulty=fault != 0.0, fault_skew=fault)
        nodes[i] = Node(i, loop, net, clock, prng.fork(300 + i), raft, ids,
                        on_leader=directory.on_leader)
    return Cluster(loop, net, nodes, directory, prng)


@dataclass
class RunResult:
    history: list[ClientLogEntry]
    reads_ok: int = 0
    reads_fail: int = 0
    writes_ok: int = 0
    writes_fail: int = 0
    read_latencies: list[float] = field(default_factory=list)
    write_latencies: list[float] = field(default_factory=list)
    linearizable_ops: int = 0
    t_start: float = 0.0            # workload start (simulated seconds)
    t_end: float = 0.0              # end of run incl. settle time
    loop_stats: dict = field(default_factory=dict)
    net_stats: dict = field(default_factory=dict)
    #: cluster-aggregated protocol counters (terms, elections, evictions,
    #: checksum drops) — the gray-failure matrix's metrics
    raft_stats: dict = field(default_factory=dict)
    #: per-node breakdown of raft_stats (the aggregation above loses
    #: which node churned — this keeps the attribution)
    raft_by_node: dict = field(default_factory=dict)
    #: flight-recorder events (run_workload(trace=True)); None when off
    trace: Optional[list] = None
    #: the unified Metrics registry the three dicts above are views of
    metrics: Optional[object] = None

    def summarize(self) -> dict:
        import statistics as st

        def pct(xs, q):
            if not xs:
                return float("nan")
            xs = sorted(xs)
            k = min(len(xs) - 1, int(q * len(xs)))
            return xs[k]

        return {
            "reads_ok": self.reads_ok, "reads_fail": self.reads_fail,
            "writes_ok": self.writes_ok, "writes_fail": self.writes_fail,
            "read_p50": pct(self.read_latencies, 0.50),
            "read_p90": pct(self.read_latencies, 0.90),
            "write_p50": pct(self.write_latencies, 0.50),
            "write_p90": pct(self.write_latencies, 0.90),
            "read_mean": st.fmean(self.read_latencies) if self.read_latencies else float("nan"),
            "write_mean": st.fmean(self.write_latencies) if self.write_latencies else float("nan"),
        }


def _attach_warm_tracer(cluster: Cluster) -> Tracer:
    """Attach a tracer to a warm-restored cluster and seed it with the
    state the boot phase already established (which the tracer missed):
    the restored leader's role and, for lease-carrying policies, the
    serving window its election no-op opened. Uses only values already
    computed — zero PRNG draws."""
    tr = Tracer(cluster.loop)
    leader = cluster.leader()
    if leader is not None and leader.is_leader():
        ctx = tr.emit("role", node=leader.id, term=leader.term,
                      parent=None, role="leader", reason="warm_start")
        leader._trace_ctx = ctx
        pol = leader.policy
        if hasattr(pol, "last_prior_term_index"):
            e = leader.log[leader.commit_index]
            tr.emit("lease", node=leader.id, term=leader.term, parent=ctx,
                    op="acquire", entry_term=e.term,
                    until=e.interval.latest + leader.p.delta,
                    limbo=len(getattr(pol, "limbo_keys", ())))
    return tr


def run_workload(raft: RaftParams, sim: SimParams,
                 fault_script: Optional[Callable[[Cluster], None]] = None,
                 check: bool = True,
                 settle_time: float = 1.0,
                 warm_start: bool = False,
                 trace: bool = False) -> RunResult:
    """End-to-end deterministic run.

    ``fault_script(cluster)`` may schedule crashes/partitions on the loop
    before the workload starts (paper §6.5 crashes the leader at t=0.5s).

    ``warm_start=True`` skips the per-seed cluster boot + election by
    restoring a cached post-election snapshot (see module docstring);
    histories differ from the cold run of the same seed but remain fully
    deterministic per (params, seed).

    ``trace=True`` attaches the flight recorder (repro.obs): the returned
    result carries the full event list in ``.trace``. Tracing draws
    nothing from any PRNG, so the run's history is bit-identical with it
    on or off.
    """
    if warm_start:
        cluster = warm_cluster(raft, sim)
        if trace:
            _attach_warm_tracer(cluster)
    else:
        cluster = build_cluster(raft, sim)
        if trace:
            # before the boot election, so the trace captures it
            Tracer(cluster.loop)
        cluster.wait_for_leader()
    loop = cluster.loop
    t0 = loop.now
    workload = Workload(loop, cluster.nodes, cluster.directory,
                        cluster.prng.fork(999), sim)
    if fault_script is not None:
        fault_script(cluster)
    loop.create_task(workload.run(sim.sim_duration))
    loop.run_until(t0 + sim.sim_duration + settle_time)
    history = workload.finalize()

    metrics = Metrics.from_cluster(cluster)
    res = RunResult(history=history, t_start=t0, t_end=loop.now,
                    loop_stats=metrics.loop_stats(),
                    net_stats=metrics.net_stats(),
                    raft_stats=metrics.raft_stats(),
                    raft_by_node=metrics.raft_stats_by_node(),
                    trace=(loop.tracer.events
                           if loop.tracer is not None else None),
                    metrics=metrics)
    for op in history:
        lat = op.end_ts - op.start_ts
        if op.op_type == "Read":
            if op.success:
                res.reads_ok += 1
                res.read_latencies.append(lat)
            else:
                res.reads_fail += 1
        else:
            if op.success:
                res.writes_ok += 1
                res.write_latencies.append(lat)
            else:
                res.writes_fail += 1
    if check:
        res.linearizable_ops = check_linearizability(history)
    return res


def throughput_timeline(history: list[ClientLogEntry], bin_size: float,
                        t_start: float, t_end: float) -> list[dict]:
    """Per-bin successful read/write counts — the paper's availability plots."""
    n_bins = int((t_end - t_start) / bin_size) + 1
    bins = [{"t": t_start + i * bin_size, "reads": 0, "writes": 0,
             "read_fail": 0, "write_fail": 0} for i in range(n_bins)]
    for op in history:
        i = int((op.end_ts - t_start) / bin_size)
        if 0 <= i < n_bins:
            b = bins[i]
            if op.op_type == "Read":
                b["reads" if op.success else "read_fail"] += 1
            else:
                b["writes" if op.success else "write_fail"] += 1
    return bins

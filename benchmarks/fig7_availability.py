"""Figs. 7 & 9: read/write availability timeline around a leader crash,
for six consistency configurations.

Setup mirrors §6.5: AWS same-subnet latencies (191 µs mean), open-loop
workload (one op / 300 µs, 1/3 writes), ET = 500 ms, Δ = 1 s (= 2·ET, to
expose the post-election no-lease window). The leader crashes 500 ms in.

Paper findings reproduced:
* log-based lease (no opts): reads+writes fail until the old lease expires;
* defer_commit: writes buffered during the wait, acked in a burst (spike);
* leaseguard (inherited reads): read availability restored immediately
  after the election (~99% of reads succeed).
"""

from __future__ import annotations

from repro.consistency import split_bench_config
from repro.core import RaftParams, SimParams, run_workload, throughput_timeline

from .common import CONFIGS, crash_leader_at


def run(quick: bool = False) -> list[dict]:
    rows = []
    bin_size = 0.1
    duration = 1.6 if quick else 2.5
    for name, config in CONFIGS.items():
        flags, sim_flags = split_bench_config(config)
        raft = RaftParams(election_timeout=0.5, election_jitter=0.1,
                          heartbeat_interval=0.05, lease_duration=1.0,
                          **flags)
        sim = SimParams(seed=7, sim_duration=duration,
                        interarrival=1e-3 if quick else 300e-6,
                        write_fraction=1 / 3, **sim_flags)
        res = run_workload(raft, sim, fault_script=crash_leader_at(0.5),
                           check=not quick, settle_time=1.5)
        t0 = min(op.start_ts for op in res.history)
        bins = throughput_timeline(res.history, bin_size, t0, t0 + duration)
        for b in bins:
            rows.append({
                "config": name,
                "t": round(b["t"] - t0, 4),
                "reads_per_s": b["reads"] / bin_size,
                "writes_per_s": b["writes"] / bin_size,
                "read_fail_per_s": b["read_fail"] / bin_size,
                "write_fail_per_s": b["write_fail"] / bin_size,
            })
    return rows


def summarize_post_election_reads(quick: bool = False) -> list[dict]:
    """Headline number: % of reads succeeding while the new leader waits
    for the old lease to expire (paper: 99% with inherited lease reads)."""
    rows = []
    for name in ("log_lease", "defer_commit", "leaseguard"):
        flags, _ = split_bench_config(CONFIGS[name])
        raft = RaftParams(election_timeout=0.5, election_jitter=0.1,
                          heartbeat_interval=0.05, lease_duration=1.0,
                          **flags)
        sim = SimParams(seed=7, sim_duration=2.5, interarrival=300e-6,
                        write_fraction=1 / 3)
        elected = {"t": None}

        def script(cluster):
            crash_leader_at(0.5)(cluster)
            first_term = cluster.directory.leader_term
            orig = cluster.directory.on_leader

            def hook(node_id, term):
                orig(node_id, term)
                if term > first_term and elected["t"] is None:
                    elected["t"] = cluster.loop.now
            for n in cluster.nodes.values():
                n.on_leader = hook

        res = run_workload(raft, sim, fault_script=script,
                           check=False, settle_time=1.5)
        # wait window: from the moment the new leader is elected until the
        # old lease expires (crash at t0+0.5, Δ = 1.0)
        t0 = min(op.start_ts for op in res.history)
        lo = elected["t"] if elected["t"] is not None else t0 + 1.2
        hi = t0 + 0.5 + 1.0
        ok = fail = 0
        for op in res.history:
            if op.op_type == "Read" and lo <= op.start_ts <= hi:
                ok += op.success
                fail += not op.success
        rows.append({"config": name, "window_reads_ok": ok,
                     "window_reads_fail": fail,
                     "window_read_success_rate": ok / max(1, ok + fail)})
    return rows

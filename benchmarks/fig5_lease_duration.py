"""Fig. 5: effect of lease duration Δ on availability after a leader crash.

Paper finding: with a fixed election timeout ET, setting Δ = ET is usually
optimal. Δ < ET buys nothing (the election gap dominates) and forces more
no-op lease extensions; Δ > ET adds a post-election window where the new
leader has no lease (mitigated by LeaseGuard's two optimizations).

We report availability = fraction of successful ops over the run, for
LeaseGuard with all optimizations, ET = 500 ms (paper's chart setting).
"""

from __future__ import annotations

from repro.core import RaftParams, SimParams, run_workload

from .common import crash_leader_at


def run(quick: bool = False) -> list[dict]:
    et = 0.5
    deltas = [0.25 * et, 0.5 * et, et, 2 * et, 4 * et]
    if quick:
        deltas = [0.5 * et, et, 2 * et]
    rows = []
    for delta in deltas:
        for name, flags in (("leaseguard", {}),
                            ("log_lease", dict(defer_commit_writes=False,
                                               inherited_lease_reads=False))):
            raft = RaftParams(election_timeout=et, election_jitter=0.1,
                              heartbeat_interval=0.05, lease_duration=delta,
                              **flags)
            sim = SimParams(seed=5, sim_duration=1.0 if quick else 3.0,
                            interarrival=2e-3 if quick else 1e-3)
            res = run_workload(raft, sim, fault_script=crash_leader_at(0.5),
                               check=not quick, settle_time=1.0)
            reads = res.reads_ok + res.reads_fail
            writes = res.writes_ok + res.writes_fail
            rows.append({
                "config": name,
                "delta_over_et": delta / et,
                "read_availability": res.reads_ok / max(1, reads),
                "write_availability": res.writes_ok / max(1, writes),
            })
    return rows

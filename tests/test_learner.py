"""The non-voting learner role (ROADMAP safe-rejoin item, paper §4.4).

A learner receives AppendEntries and applies state but is excluded from
``majority()``, withholds votes, and never starts elections; the leader
promotes it to voter via an ordinary CONFIG entry once its match index
covers the commit index. The safe disk-loss path layers on top: a wiped
node rejoins as a forced learner, is demoted in the replicated config,
catches up, and is promoted back.
"""

from repro.core import RaftParams, SimParams, build_cluster
from repro.core.raft import (CONFIG, AppendEntries, RequestVote,
                             encode_config, parse_config)


def make(**kw):
    kw.setdefault("lease_duration", 2.0)
    kw.setdefault("election_timeout", 0.5)
    raft = RaftParams(**kw)
    return build_cluster(raft, SimParams()), raft


def settle(c, dt):
    c.loop.run_until(c.loop.now + dt)


def run(c, coro):
    return c.loop.run_until_complete(c.loop.create_task(coro))


def add_learner(c, ldr, raft, node_id):
    node = c.spawn_node(node_id, raft, learner=True)
    res = run(c, ldr.change_membership(set(ldr.config),
                                       learners=set(ldr.learners) | {node_id}))
    assert res.ok
    return node


# ------------------------------------------------------------ the role itself
def test_learner_replicates_but_is_excluded_from_majority():
    c, raft = make(auto_promote_learners=False)
    ldr = c.wait_for_leader()
    assert run(c, ldr.client_write("x", 1)).ok
    learner = add_learner(c, ldr, raft, 3)
    settle(c, 0.5)
    # state machine caught up, yet the quorum arithmetic ignores it
    assert learner.data.get("x") == [1]
    assert ldr.majority() == 2                   # |{0,1,2}| // 2 + 1
    assert ldr.learners == {3}
    assert 3 in ldr.next_index                   # replicated to, though
    # one follower down: {leader, follower} is still a voter majority
    followers = [n for n in c.nodes.values()
                 if n is not ldr and n is not learner]
    followers[0].crash()
    assert run(c, ldr.client_write("x", 2)).ok
    settle(c, 0.3)
    assert learner.data.get("x") == [1, 2]
    # both voters down: a caught-up learner must NOT complete the quorum
    followers[1].crash()
    res = run(c, ldr.client_write("x", 3), )
    assert not res.ok


def test_learner_withholds_votes():
    c, raft = make(auto_promote_learners=False)
    ldr = c.wait_for_leader()
    learner = add_learner(c, ldr, raft, 3)
    settle(c, 0.3)
    # even a maximally up-to-date candidate gets nothing from a learner
    reply = learner._handle_vote(
        0, RequestVote(learner.term + 1, 0, 10_000, learner.term + 1))
    assert not reply.granted
    assert learner.voted_for is None or learner.voted_for != 0


def test_learner_never_starts_elections():
    c, raft = make(auto_promote_learners=False)
    ldr = c.wait_for_leader()
    learner = add_learner(c, ldr, raft, 3)
    settle(c, 0.3)
    term0 = learner.term
    for n in list(c.nodes.values()):
        if n is not learner:
            n.crash()
    settle(c, 3.0)                 # several election timeouts elapse
    assert learner.state == "follower"
    assert learner.term == term0   # no candidacy, no term inflation


def test_auto_promotion_once_caught_up():
    c, raft = make()
    ldr = c.wait_for_leader()
    assert run(c, ldr.client_write("x", 1)).ok
    learner = add_learner(c, ldr, raft, 3)
    settle(c, 1.0)
    # the leader's replication loop promoted it via a CONFIG entry
    assert 3 in ldr.config and ldr.learners == set()
    assert ldr.majority() == 3                   # four voters now
    assert learner.config == {0, 1, 2, 3}
    configs = [e.value for e in ldr.log if e.key == CONFIG]
    assert parse_config(configs[-2])[1] == {3}   # joined as learner...
    assert parse_config(configs[-1])[0] == {0, 1, 2, 3}   # ...then voter
    # and it votes like any member afterwards
    reply = learner._handle_vote(
        0, RequestVote(learner.term + 1, 0, 10_000, learner.term + 1))
    assert reply.granted


def test_config_codec_roundtrip():
    assert parse_config(encode_config({1, 0, 2})) == ({0, 1, 2}, set())
    assert parse_config(encode_config({0, 1}, {2})) == ({0, 1}, {2})
    assert encode_config({2, 0, 1}) == [0, 1, 2]          # legacy shape
    assert parse_config([0, 1, 2]) == ({0, 1, 2}, set())  # legacy logs


# ------------------------------------------------------- safe disk-loss path
def wipe_and_demote(c, ldr, victim):
    """The DiskLossRejoin choreography, step by step."""
    victim.crash()
    res = run(c, ldr.change_membership(set(ldr.config) - {victim.id},
                                       learners=set(ldr.learners)
                                       | {victim.id}))
    assert res.ok
    victim.restart(wipe_disk=True, rejoin_as_learner=True)


def test_wiped_learner_never_votes_before_promotion():
    c, raft = make()
    ldr = c.wait_for_leader()
    for i in range(5):
        assert run(c, ldr.client_write("k", i)).ok
    victim = next(n for n in c.nodes.values() if n is not ldr)
    wipe_and_demote(c, ldr, victim)
    # freshly wiped: empty log, forced-learner, zero voting power
    assert victim.is_learner()
    reply = victim._handle_vote(
        0, RequestVote(victim.term + 1, 0, 10_000, victim.term + 1))
    assert not reply.granted
    assert victim.id not in ldr.config           # demoted from the quorum
    assert ldr.majority() == 2                   # of voters {ldr, other}
    settle(c, 1.5)                               # catch up + auto-promote
    assert victim.id in ldr.config and not victim.is_learner()
    assert victim.data.get("k") == [0, 1, 2, 3, 4]
    reply = victim._handle_vote(
        0, RequestVote(victim.term + 1, 0, 10_000, victim.term + 1))
    assert reply.granted                         # full member again


def test_wiped_learner_match_index_clamped_before_recount():
    """Leader-side: a wiped node's stale match_index must be clamped on
    first contact, so its lost log is never counted toward a commit."""
    c, raft = make()
    ldr = c.wait_for_leader()
    for i in range(5):
        assert run(c, ldr.client_write("k", i)).ok
    victim = next(n for n in c.nodes.values() if n is not ldr)
    settle(c, 0.2)
    m0 = ldr.match_index[victim.id]
    assert m0 >= 5
    victim.restart(wipe_disk=True, rejoin_as_learner=True)
    # step the loop until the leader's record first moves: the move must
    # be DOWN (the failure reply carries the wiped node's last log index)
    deadline = c.loop.now + 1.0
    while ldr.match_index.get(victim.id) == m0 and c.loop.now < deadline:
        c.loop._step()
    assert ldr.match_index[victim.id] == 0
    settle(c, 1.0)                               # then it regrows honestly
    assert ldr.match_index[victim.id] >= m0


def test_forced_learner_ignores_stale_voter_configs():
    """Old CONFIG entries (listing the wiped node as a voter, from a
    pre-wipe membership stint) re-arrive during catch-up; the forced-
    learner flag must hold through them. Content-based clearing can't
    tell that old stint's configs from the post-wipe demotion — the flag
    only clears once the log provably covers the cluster commit point."""
    c, raft = make()
    ldr = c.wait_for_leader()
    new = c.spawn_node(3, raft, learner=True)
    assert run(c, ldr.change_membership(set(ldr.config),
                                        learners={3})).ok
    settle(c, 1.0)
    assert 3 in ldr.config                       # promoted: config history
    victim = new                                 # has voter CONFIG for 3
    wipe_and_demote(c, ldr, victim)
    assert victim._forced_learner
    # replay the stale prefix by hand: first its own add-as-learner
    # CONFIG, then its old promote-to-voter CONFIG — neither may clear
    # the flag while the log still trails the commit point
    prefix = ldr.log[1:]
    demote_at = max(i for i, e in enumerate(ldr.log)
                    if e.key == CONFIG and 3 in parse_config(e.value)[1])
    stale = prefix[:demote_at - 1]               # everything pre-demotion
    victim._handle_append(ldr.id, AppendEntries(
        victim.term, ldr.id, 0, 0, stale, ldr.commit_index))
    assert victim._forced_learner                # stale voter config ignored
    assert victim.is_learner()                   # despite config saying voter
    reply = victim._handle_vote(
        0, RequestVote(victim.term + 1, 0, 10_000, victim.term + 1))
    assert not reply.granted
    # the rest of the log arrives and commit coverage is proven: the
    # flag clears, and the (current) config — learner — takes over
    victim._handle_append(ldr.id, AppendEntries(
        victim.term, ldr.id, len(stale), stale[-1].term,
        prefix[len(stale):], ldr.commit_index))
    assert not victim._forced_learner
    assert victim.is_learner()                   # now by config, not fiat


def test_append_failure_reply_carries_last_log_index():
    c, raft = make()
    ldr = c.wait_for_leader()
    f = next(n for n in c.nodes.values() if n is not ldr)
    settle(c, 0.2)
    last = f.last_log_index
    reply = f._handle_append(ldr.id, AppendEntries(
        f.term, ldr.id, last + 50, f.term, [], 0))
    assert not reply.success and reply.match_index == last

"""Pure-jnp oracles for the Pallas kernels (the source of truth in
kernel allclose tests)."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        window: Optional[int] = None) -> jax.Array:
    """q: (BH, Sq, hd); k/v: (BHkv, Sk, hd). Dense causal attention with
    GQA via explicit repeat — O(S^2) memory, small-shape oracle only."""
    bh, sq, hd = q.shape
    bhkv, sk, _ = k.shape
    n_rep = bh // bhkv
    k = jnp.repeat(k, n_rep, axis=0)
    v = jnp.repeat(v, n_rep, axis=0)
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def wkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
             u: jax.Array) -> jax.Array:
    """Token-by-token WKV6 recurrence (fp32). r,k,v,w: (BH,S,hd);
    u: (BH,hd)."""
    def step(state, rkvw):
        rt, kt, vt, wt = rkvw
        kv = kt[:, :, None] * vt[:, None, :]
        y = jnp.einsum("bi,bij->bj", rt,
                       state + u[:, :, None] * kv)
        return wt[:, :, None] * state + kv, y

    bh, s, hd = r.shape
    state0 = jnp.zeros((bh, hd, hd), jnp.float32)
    seq = tuple(x.astype(jnp.float32).transpose(1, 0, 2) for x in (r, k, v, w))
    _, ys = jax.lax.scan(step, state0, seq)
    return ys.transpose(1, 0, 2)

"""All protocol + simulation parameters in one place (paper §6.1 params.py)."""

from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Optional


class ReadMode(enum.Enum):
    """User-facing consistency switch. Each value is resolved to a
    ConsistencyPolicy class by the registry in ``repro.consistency`` —
    the value string equals the policy's ``name``."""

    INCONSISTENT = "inconsistent"    # local read, no consistency mechanism
    QUORUM = "quorum"                # Raft's default: per-read majority check
    ONGARO_LEASE = "ongaro_lease"    # heartbeat-based lease ([41] §6.4.1)
    LEASEGUARD = "leaseguard"        # this paper: the log is the lease
    READ_INDEX = "readindex"         # Raft ReadIndex: batched read barrier
    FOLLOWER_READ = "follower_read"  # leased leader barrier + follower serve


@dataclass
class RaftParams:
    n_nodes: int = 3
    election_timeout: float = 0.5          # ET
    election_jitter: float = 0.2           # uniform extra per election cycle
    heartbeat_interval: float = 0.05
    rpc_timeout: float = 0.25
    lease_duration: Optional[float] = None  # Δ; defaults to ET when None
    read_mode: ReadMode = ReadMode.LEASEGUARD
    # LeaseGuard optimization flags (paper §3.2, §3.3). With both False,
    # this is the "log-based lease" configuration of Figs. 7/9.
    defer_commit_writes: bool = True
    inherited_lease_reads: bool = True
    # lease upkeep (paper §5.1)
    noop_on_election: bool = True
    lease_maintenance: bool = True          # proactive no-op before expiry
    # membership: the leader's replication loop promotes a learner to
    # voter (one CONFIG entry) once its match_index covers commitIndex
    auto_promote_learners: bool = True
    # --- gray-failure resilience (all OFF by default: every committed
    # artifact replays bit-identically, and the disabled code paths make
    # no PRNG draws) ---
    # PreVote: a would-be candidate polls a majority with a trial
    # (non-term-bumping) vote before incrementing its term, so a flapping
    # node cannot inflate terms and evict a healthy lease-holding leader
    prevote: bool = False
    # CheckQuorum: a leader that has not heard from a voting majority
    # within an election timeout steps down (and stops serving its lease)
    # instead of serving a doomed lease window
    check_quorum: bool = False
    # capped exponential backoff + jitter on per-peer AppendEntries RPC
    # timeouts, replacing the fixed rpc_timeout hot-loop against
    # slow/dead peers
    replication_backoff: bool = False
    backoff_base: float = 0.02       # first retry delay after a timeout
    backoff_max: float = 0.5         # cap on the exponential growth
    # end-to-end checksums on AppendEntries (header digest + per-entry
    # checksums): corrupted messages are detected and dropped instead of
    # applied (the corruption nemesis tier's defense)
    entry_checksums: bool = False
    # clocks (paper §2.2; AWS clock-bound preset is 50 µs)
    max_clock_error: float = 50e-6
    # client-visible timeouts
    write_timeout: float = 2.0
    read_timeout: float = 2.0
    batch_max_entries: int = 128

    @property
    def delta(self) -> float:
        return self.lease_duration if self.lease_duration is not None else self.election_timeout


@dataclass
class SimParams:
    seed: int = 1
    one_way_latency_mean: float = 191e-6    # AWS same-subnet (paper §6.5)
    one_way_latency_variance: float = 391e-6 ** 2
    io_service_time: float = 0.0            # >0 models I/O contention (Figs. 9-11)
    sim_duration: float = 3.0
    # workload (open loop, paper §6.3-6.6)
    interarrival: float = 300e-6            # mean gap between client arrivals
    write_fraction: float = 1.0 / 3.0
    n_keys: int = 1000
    zipf_a: float = 0.0                     # 0 = uniform
    value_size: int = 1024
    # fraction of reads routed to a non-leader replica (only useful with a
    # policy that can serve them, e.g. ReadMode.FOLLOWER_READ)
    follower_read_fraction: float = 0.0

"""Policy × fleet-scenario × seed matrix over the training-fleet
simulator, plus the fleet-size scale sweep.

For every registered consistency policy and every named fleet scenario
(data-plane chaos, control-plane chaos, and combined schedules) this
runs ``repro.fleet.run_fleet`` over many seeds, audits checkpoint
lineage omnisciently, and writes ``BENCH_fleet_matrix.json`` at the
repo root. Reduced slices (``--smoke``, ``--policies``, ``--scenarios``,
fewer seeds) write ``BENCH_fleet_matrix_smoke.json`` instead.

The contract the matrix enforces (and CI smoke-checks):

* every **consistent** policy × every fleet scenario × every seed has
  ZERO lineage violations (no forks, durable restores, staleness bound);
* the **inconsistent** baseline is flagged under partition scenarios —
  the positive control proving the lineage checker bites;
* per-policy coordinator message load per worker-step shows
  leaseguard ≪ quorum — the paper's claim that zero-roundtrip reads
  make the fleet-wide checkpoint-poll loop free, measured at fleet
  scale by the ``--scale`` sweep (fleet sizes × {leaseguard, quorum}).

Usage:
    python benchmarks/fleet_matrix.py [--seeds N] [--smoke]
        [--scenarios a,b] [--policies x,y] [--jobs N] [--no-scale]
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.consistency import benchmark_configs, split_bench_config  # noqa: E402
from repro.core import RaftParams, SimParams  # noqa: E402
from repro.fleet import (FleetParams, build_fleet_scenario,  # noqa: E402
                         fleet_scenario_names, run_fleet)

REPO_ROOT = Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_fleet_matrix.json"
SMOKE_OUT_PATH = REPO_ROOT / "BENCH_fleet_matrix_smoke.json"

NON_LINEARIZABLE = {"inconsistent"}

#: scenarios under which the inconsistent baseline is expected to restore
#: from stale manifests (the positive control): anything that partitions
#: or kills the Raft leader while workers restore.
PARTITION_SCENARIOS = {"partition_churn", "leader_crash_mid_commit",
                       "leader_nemesis_fleet", "chief_and_leader_die"}

DEFAULT_SEEDS = 8
#: fleet sizes for the quorum-poll-bottleneck scale sweep
SCALE_WORKERS = [4, 16, 48]
SCALE_POLICIES = ["leaseguard", "quorum"]
SCALE_SEEDS = 2
SCALE_DURATION = 2.0
#: leaseguard must carry at most this fraction of quorum's per-step load
LOAD_RATIO_MAX = 0.5


def policy_configs() -> dict[str, dict]:
    return benchmark_configs(variants=False)


def _raft(policy: str, overrides: dict) -> RaftParams:
    flags, _sim_flags = split_bench_config(policy_configs()[policy])
    return RaftParams(election_timeout=0.3, election_jitter=0.1,
                      heartbeat_interval=0.03, lease_duration=0.6,
                      rpc_timeout=0.15, **{**flags, **overrides})


def _fleet_params(policy: str, **kw) -> FleetParams:
    # clients of the no-consistency baseline read whatever replica is
    # cheapest — same modelling trick as the workload matrix's
    # follower_read_fraction
    if policy in NON_LINEARIZABLE:
        kw.setdefault("read_any_fraction", 0.3)
    return FleetParams(**kw)


def run_cell(policy: str, scenario_name: str, seed: int) -> dict:
    """One deterministic fleet run; returns a JSON-ready row."""
    sc = build_fleet_scenario(scenario_name)
    res = run_fleet(_raft(policy, sc.raft_overrides), SimParams(seed=seed),
                    _fleet_params(policy), sc)
    row = {"policy": policy, "scenario": scenario_name, "seed": seed}
    row.update(res.summarize())
    # full violation detail only when something fired (rows stay compact)
    if res.violations:
        row["violation_detail"] = res.violations[:10]
        # identical traced replay (tracing draws nothing from any PRNG):
        # the digest names the election/partition behind the lineage break
        from repro.obs.explain import trace_digest
        tres = run_fleet(_raft(policy, sc.raft_overrides),
                         SimParams(seed=seed), _fleet_params(policy),
                         build_fleet_scenario(scenario_name), trace=True)
        ev = tres.events
        t0 = ev[0]["t"] if ev else 0.0
        t1 = ev[-1]["t"] if ev else 0.0
        row["trace_digest"] = trace_digest(ev, t0, t1)
    return row


def run_scale_cell(policy: str, n_workers: int, seed: int) -> dict:
    res = run_fleet(_raft(policy, {}), SimParams(seed=seed),
                    _fleet_params(policy, n_workers=n_workers,
                                  duration=SCALE_DURATION),
                    build_fleet_scenario("calm"))
    return {"policy": policy, "n_workers": n_workers, "seed": seed,
            "total_steps": res.total_steps, "messages": res.messages,
            "messages_per_step": round(res.messages_per_step, 3),
            "violations": len(res.violations)}


def _cell_args(policies, scenarios, seeds):
    return [(p, s, seed) for p in policies for s in scenarios
            for seed in seeds]


def run_matrix(policies: list[str], scenarios: list[str], seeds: list[int],
               jobs: int = 1, progress: bool = True) -> list[dict]:
    """Run the cube; byte-identical output for any ``jobs`` (round-robin
    shard + ordered merge, same scheme as the fault matrix)."""
    cells = _cell_args(policies, scenarios, seeds)
    if jobs > 1:
        from concurrent.futures import ProcessPoolExecutor
        shards = [cells[k::jobs] for k in range(jobs)]
        with ProcessPoolExecutor(max_workers=jobs) as ex:
            shard_rows = list(ex.map(_run_shard, shards))
        iters = [iter(sr) for sr in shard_rows]
        rows = [next(iters[i % jobs]) for i in range(len(cells))]
    else:
        rows = []
        for i, cell in enumerate(cells):
            rows.append(run_cell(*cell))
            if progress and (i + 1) % 50 == 0:
                print(f"# {i + 1}/{len(cells)} cells", file=sys.stderr)
    rows.sort(key=lambda r: (r["policy"], r["scenario"], r["seed"]))
    return rows


def _run_shard(cells) -> list[dict]:
    return [run_cell(*cell) for cell in cells]


def summarize(rows: list[dict]) -> list[dict]:
    """Per (policy, scenario): lineage verdicts + the headline metrics."""
    agg: dict[tuple[str, str], dict] = {}
    for r in rows:
        a = agg.setdefault((r["policy"], r["scenario"]), {
            "policy": r["policy"], "scenario": r["scenario"], "seeds": 0,
            "violation_cells": 0, "violations": 0, "total_steps": 0,
            "stale_polls": 0, "chief_deaths": 0,
            "_mps": [], "_steps_lost": [], "_recov": []})
        a["seeds"] += 1
        a["violation_cells"] += 1 if r["violations"] else 0
        a["violations"] += r["violations"]
        a["total_steps"] += r["total_steps"]
        a["stale_polls"] += r["stale_polls"]
        a["chief_deaths"] += r["chief_deaths"]
        a["_mps"].append(r["messages_per_step"])
        a["_steps_lost"].extend(r["steps_lost"])
        a["_recov"].extend([t for t in r["chief_recovery"] if t is not None]
                           + r["leader_recovery"])
    out = []
    for key in sorted(agg):
        a = agg[key]
        a["messages_per_step"] = round(statistics.fmean(a.pop("_mps")), 3)
        lost = a.pop("_steps_lost")
        a["mean_steps_lost"] = round(statistics.fmean(lost), 2) if lost else 0
        recov = a.pop("_recov")
        a["mean_recovery"] = round(statistics.fmean(recov), 3) if recov else None
        out.append(a)
    return out


class FleetMatrixError(AssertionError):
    """The matrix contract failed: a consistent policy broke checkpoint
    lineage, the positive control came up empty, or leaseguard's message
    load is not ≪ quorum's."""


def run(quick: bool = False) -> list[dict]:
    """benchmarks.run entry point: full matrix, or the CI smoke slice."""
    return main(["--smoke"] if quick else [])


def main(argv=None) -> list[dict]:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seeds", type=int, default=DEFAULT_SEEDS,
                    help=f"seeds per cell (default {DEFAULT_SEEDS})")
    ap.add_argument("--scenarios", default=None,
                    help="comma-separated fleet scenario names (default: all)")
    ap.add_argument("--policies", default=None,
                    help="comma-separated policy names (default: all)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI slice: 3 policies x 3 scenarios x 3 seeds")
    ap.add_argument("--no-scale", action="store_true",
                    help="skip the fleet-size scale sweep")
    ap.add_argument("--jobs", type=int,
                    default=max(1, (os.cpu_count() or 2) - 1))
    ap.add_argument("--out", default=None,
                    help="artifact path (default: BENCH_fleet_matrix.json; "
                         "reduced slices go to BENCH_fleet_matrix_smoke.json)")
    args = ap.parse_args(argv)

    policies = list(policy_configs())
    scenarios = fleet_scenario_names()
    seeds = list(range(args.seeds))
    if args.smoke:
        policies = ["leaseguard", "quorum", "inconsistent"]
        scenarios = ["calm", "chief_kill", "partition_churn"]
        seeds = list(range(3))
    if args.scenarios:
        scenarios = args.scenarios.split(",")
    if args.policies:
        policies = args.policies.split(",")
    full_cube = (not args.smoke and not args.scenarios and not args.policies
                 and args.seeds >= DEFAULT_SEEDS)
    out_path = args.out or str(OUT_PATH if full_cube else SMOKE_OUT_PATH)

    n = len(policies) * len(scenarios) * len(seeds)
    print(f"# fleet matrix: {len(policies)} policies x {len(scenarios)} "
          f"scenarios x {len(seeds)} seeds = {n} cells (jobs={args.jobs})",
          file=sys.stderr)
    rows = run_matrix(policies, scenarios, seeds, jobs=args.jobs)
    summary = summarize(rows)

    scale_rows: list[dict] = []
    if not args.no_scale:
        workers = SCALE_WORKERS[:2] if args.smoke else SCALE_WORKERS
        n_seeds = 1 if args.smoke else SCALE_SEEDS
        for p in SCALE_POLICIES:
            for nw in workers:
                for seed in range(n_seeds):
                    scale_rows.append(run_scale_cell(p, nw, seed))
        print(f"# scale sweep: {len(scale_rows)} cells", file=sys.stderr)

    consistent = [p for p in policies if p not in NON_LINEARIZABLE]
    bad = [r for r in rows if r["violations"] and r["policy"] in consistent]
    control = [r for r in rows
               if r["violations"] and r["policy"] in NON_LINEARIZABLE]
    # the control has teeth only when the baseline actually ran against
    # partition-class scenarios over enough seeds to make staleness likely
    control_expected = (set(policies) & NON_LINEARIZABLE
                        and set(scenarios) & PARTITION_SCENARIOS
                        and len(seeds) >= 5)

    # the paper's headline: per-step message load, leaseguard vs quorum
    load = {}
    for p in set(SCALE_POLICIES) & set(policies):
        mps = [r["messages_per_step"] for r in rows
               if r["policy"] == p and r["scenario"] == "calm"]
        if mps:
            load[p] = round(statistics.fmean(mps), 3)

    artifact = {
        "policies": policies,
        "scenarios": scenarios,
        "seeds": seeds,
        "consistent_policies": consistent,
        "consistent_violations": len(bad),
        "inconsistent_violations": len(control),
        "calm_messages_per_step": load,
        "summary": summary,
        "scale": scale_rows,
        "cells": rows,
    }
    Path(out_path).write_text(json.dumps(artifact, indent=2, sort_keys=True)
                              + "\n")
    print(f"# wrote {out_path}", file=sys.stderr)

    for s in summary:
        print(f"{s['policy']:14s} {s['scenario']:26s} "
              f"seeds={s['seeds']:3d} violations={s['violations']:3d} "
              f"msgs/step={s['messages_per_step']:6.2f} "
              f"steps_lost={s['mean_steps_lost']}")
    for r in scale_rows:
        print(f"scale {r['policy']:12s} n_workers={r['n_workers']:3d} "
              f"seed={r['seed']} msgs/step={r['messages_per_step']:6.2f}")

    if bad:
        msg = (f"{len(bad)} lineage-violating cells in consistent policies")
        print(f"\nFAIL: {msg}:", file=sys.stderr)
        for r in bad[:10]:
            print(f"  {r['policy']} / {r['scenario']} / seed {r['seed']}: "
                  f"{r.get('violation_detail')}", file=sys.stderr)
        raise FleetMatrixError(msg)
    if control_expected and not control:
        msg = ("positive control failed: the inconsistent baseline was "
               "never flagged under partition scenarios — is the lineage "
               "checker vacuous?")
        print(f"\nFAIL: {msg}", file=sys.stderr)
        raise FleetMatrixError(msg)
    if "leaseguard" in load and "quorum" in load:
        if load["leaseguard"] > load["quorum"] * LOAD_RATIO_MAX:
            msg = (f"message-load contract failed: leaseguard "
                   f"{load['leaseguard']} msgs/step is not ≪ quorum "
                   f"{load['quorum']}")
            print(f"\nFAIL: {msg}", file=sys.stderr)
            raise FleetMatrixError(msg)
    print(f"\n# zero lineage violations across {len(consistent)} consistent "
          f"policies"
          + (f"; inconsistent baseline flagged in {len(control)} cells"
             if control_expected or control else "")
          + (f"; calm msgs/step {load}" if load else ""))
    return summary


if __name__ == "__main__":
    try:
        main()
    except FleetMatrixError:
        sys.exit(1)

"""Per-architecture smoke tests (reduced configs): one forward/train step
on CPU, asserting output shapes and no NaNs; plus a prefill/decode
consistency check per family."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.models import (decode_step, forward_train, init_decode_cache,
                          init_params, prefill)

ARCH_IDS = sorted(ARCHS)


def make_batch(cfg, key, batch=2, seq=16):
    kt, kl, ke = jax.random.split(key, 3)
    batch_d = {"labels": jax.random.randint(kl, (batch, seq), 0,
                                            cfg.vocab_size)}
    if cfg.embedding_stub:
        batch_d["embeds"] = jax.random.normal(
            ke, (batch, seq, cfg.d_model), jnp.float32) * 0.02
    else:
        batch_d["tokens"] = jax.random.randint(kt, (batch, seq), 0,
                                               cfg.vocab_size)
    return batch_d


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_train_loss_finite(arch_id):
    cfg = ARCHS[arch_id].reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = make_batch(cfg, key)
    loss = jax.jit(lambda p, b: forward_train(p, cfg, b))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch_id}: loss={loss}"
    # a tiny vocab's random-init CE should be near log(V)
    assert 0.1 < float(loss) < 3 * jnp.log(cfg.vocab_size)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_step_grads_finite(arch_id):
    cfg = ARCHS[arch_id].reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    batch = make_batch(cfg, key)
    grads = jax.jit(jax.grad(lambda p: forward_train(p, cfg, batch)))(params)
    flat = jax.tree.leaves(grads)
    assert flat, "no grads"
    for g in flat:
        assert jnp.all(jnp.isfinite(g.astype(jnp.float32)))
    # gradients must reach the embedding/first-layer params
    if not cfg.embedding_stub:
        assert float(jnp.abs(grads["embed"].astype(jnp.float32)).max()) > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_prefill_then_decode_matches_full_forward(arch_id):
    """Decode with caches must agree with the full-sequence forward."""
    cfg = ARCHS[arch_id].reduced()
    if cfg.embedding_stub:
        pytest.skip("stub-frontend archs decode from embeddings; covered "
                    "by test_decode_step_runs_stub")
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    b, s = 2, 12
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)

    # ground truth: last-token logits from a full prefill of all s tokens
    logits_full, _, _ = prefill(params, cfg, {"tokens": tokens})

    # prefill s-1 tokens, then decode token s-1
    logits_pre, caches, pos = prefill(params, cfg,
                                      {"tokens": tokens[:, :-1]})
    if not cfg.attn_free:
        # grow the kv cache to hold the decode token
        def grow(c):
            pad = [(0, 0)] * c.ndim
            pad[2] = (0, 4)  # (L, B, S, H, hd): pad S
            return jnp.pad(c, pad)
        caches = jax.tree.map(
            lambda c: grow(c) if c.ndim == 5 else c, caches)
    logits_dec, _ = decode_step(params, cfg, tokens[:, -1], caches, pos)
    assert jnp.allclose(logits_dec, logits_full, atol=2e-2, rtol=2e-2), \
        f"{arch_id}: max diff {jnp.abs(logits_dec - logits_full).max()}"


@pytest.mark.parametrize("arch_id", [a for a in ARCH_IDS
                                     if ARCHS[a].embedding_stub])
def test_decode_step_runs_stub(arch_id):
    cfg = ARCHS[arch_id].reduced()
    key = jax.random.PRNGKey(3)
    params = init_params(key, cfg)
    b = 2
    caches = init_decode_cache(cfg, b, max_len=8)
    embeds = jax.random.normal(key, (b, cfg.d_model), jnp.float32)
    logits, new_caches = decode_step(params, cfg, embeds, caches,
                                     jnp.zeros((b,), jnp.int32))
    assert logits.shape == (b, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits))


@pytest.mark.parametrize("arch_id", ["rwkv6-3b", "hymba-1.5b"])
def test_stateful_decode_sequence(arch_id):
    """SSM/hybrid archs: decoding token-by-token from blank state matches
    the full-sequence forward (state carries all history)."""
    cfg = ARCHS[arch_id].reduced()
    key = jax.random.PRNGKey(4)
    params = init_params(key, cfg)
    b, s = 1, 6
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    logits_full, _, _ = prefill(params, cfg, {"tokens": tokens})

    caches = init_decode_cache(cfg, b, max_len=s + 1)
    logits = None
    for i in range(s):
        logits, caches = decode_step(params, cfg, tokens[:, i], caches,
                                     jnp.full((b,), i, jnp.int32))
    assert jnp.allclose(logits, logits_full, atol=2e-2, rtol=2e-2), \
        f"{arch_id}: max diff {jnp.abs(logits - logits_full).max()}"

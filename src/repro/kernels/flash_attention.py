"""Pallas TPU flash attention: blockwise causal attention with online
softmax, GQA (grouped KV indexing — KV never materialized per-q-head),
and optional sliding window.

TPU adaptation (not a CUDA port): the grid is (batch·q_heads, q_blocks,
k_blocks) iterated sequentially per core with VMEM-resident accumulators
(o_acc, running max m, denominator l) carried across the k_block
dimension — the Pallas/TPU analogue of a persistent-CTA flash kernel.
Block shapes are MXU-aligned (q/k blocks multiples of 128 where the
sequence allows; head_dim padded by the caller when < 128 is needed).
Scores and probabilities live only in VMEM: HBM traffic is Q+K+V+O, which
is what the roofline's kernel-adjusted memory term assumes.

Safety: k_blocks that are fully masked (causal/window) contribute nothing;
they are computed-and-masked rather than skipped, keeping the kernel
grid static (Pallas TPU requires a static grid).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, block_q: int, block_k: int, n_k_blocks: int,
               window, seq_q: int, seq_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                      # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                      # (bk, hd)
    v = v_ref[0].astype(jnp.float32)                      # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = (qpos >= kpos) & (qpos < seq_q) & (kpos < seq_k)
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                   # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                                # (bq, bk)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_k_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        window=None, block_q: int = 128,
                        block_k: int = 128,
                        interpret: bool = False) -> jax.Array:
    """q: (BH, Sq, hd); k/v: (BHkv, Sk, hd) with BH % BHkv == 0 (GQA:
    the kernel indexes the shared KV head — no repeat in HBM)."""
    bh, sq, hd = q.shape
    bhkv, sk, _ = k.shape
    assert bh % bhkv == 0
    n_rep = bh // bhkv
    scale = 1.0 / math.sqrt(hd)

    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    n_q = pl.cdiv(sq, block_q)
    n_k = pl.cdiv(sk, block_k)

    kernel = functools.partial(
        _fa_kernel, scale=scale, block_q=block_q, block_k=block_k,
        n_k_blocks=n_k, window=window, seq_q=sq, seq_k=sk)

    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b // n_rep, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b // n_rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # denominator l
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)

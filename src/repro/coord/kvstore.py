"""In-process coordination service: a LeaseGuard Raft replica set driven
by a crank adapter.

The deterministic simulator (repro.core) models time explicitly; the
trainer lives in wall-clock time. The adapter bridges them: each client
call cranks the simulated event loop forward until the operation's future
resolves (or a simulated timeout passes). One simulated replica set =
one coordination service; fault injection (crash_leader, partition) is
exposed for tests, examples, and failover drills.
"""

from __future__ import annotations

import json
from typing import Any, Optional, Union

from ..consistency import resolve_read_mode
from ..core import (Cluster, RaftParams, ReadMode, SimParams, build_cluster)


class CoordinatorError(RuntimeError):
    pass


class LocalCoordinator:
    """Replicated, linearizable KV (append-only lists per key) with
    LeaseGuard zero-roundtrip reads by default; any policy from the
    ``repro.consistency`` registry can be selected by enum or name."""

    def __init__(self, n_nodes: int = 3, seed: int = 0,
                 read_mode: Union[ReadMode, str] = ReadMode.LEASEGUARD,
                 lease_duration: float = 1.0) -> None:
        self.read_mode = resolve_read_mode(read_mode)
        raft = RaftParams(n_nodes=n_nodes, read_mode=self.read_mode,
                          election_timeout=0.5, heartbeat_interval=0.05,
                          lease_duration=lease_duration)
        sim = SimParams(seed=seed)
        self.cluster: Cluster = build_cluster(raft, sim)
        self.cluster.wait_for_leader()
        self.reads = 0
        self.read_messages = 0

    # -- crank ----------------------------------------------------------
    def _run(self, coro, max_sim_time: float = 30.0):
        loop = self.cluster.loop
        task = loop.create_task(coro)
        deadline = loop.now + max_sim_time
        while not task.done() and loop.now < deadline:
            loop.run_until(loop.now + 0.01)
        if not task.done():
            raise CoordinatorError("coordinator operation timed out")
        return task.result()

    def _leader(self):
        ldr = self.cluster.leader()
        if ldr is None or not ldr.alive:
            # crank until a leader exists (failover in progress)
            self.cluster.wait_for_leader()
            ldr = self.cluster.leader()
        if ldr is None:
            raise CoordinatorError("no leader")
        return ldr

    # -- public KV API ----------------------------------------------------
    def append(self, key: str, value: Any, retries: int = 5) -> None:
        """Linearizable durable write (committed through the Raft log)."""
        payload = json.dumps(value)
        for _ in range(retries):
            ldr = self._leader()
            res = self._run(ldr.client_write(key, payload))
            if res.ok:
                return
            # not_leader / no_lease / timeout: crank forward and retry
            self.cluster.loop.run_until(self.cluster.loop.now + 0.3)
        raise CoordinatorError(f"write failed after {retries} retries")

    def read_list(self, key: str, retries: int = 5) -> list:
        """Linearizable read — zero network roundtrips under LeaseGuard."""
        for _ in range(retries):
            ldr = self._leader()
            before = self.cluster.net.messages_sent
            res = self._run(ldr.client_read(key))
            if res.ok:
                self.reads += 1
                self.read_messages += self.cluster.net.messages_sent - before
                return [json.loads(v) for v in res.value]
            self.cluster.loop.run_until(self.cluster.loop.now + 0.3)
        raise CoordinatorError(f"read failed after {retries} retries")

    def read_latest(self, key: str) -> Optional[Any]:
        xs = self.read_list(key)
        return xs[-1] if xs else None

    # -- elastic scaling (paper §4.4 single-node reconfiguration) ---------
    def add_node(self, wait_for_promotion: bool = True,
                 max_sim_time: float = 30.0) -> int:
        """Add one fresh replica the safe way: it joins as a non-voting
        learner (receives and applies the log, counts toward nothing),
        and the leader promotes it to voter via an ordinary CONFIG entry
        once its match index covers the commit index."""
        new_id = max(self.cluster.nodes) + 1
        ldr = self._leader()
        self.cluster.spawn_node(new_id, ldr.p, learner=True)
        res = self._run(ldr.change_membership(
            set(ldr.config), learners=set(ldr.learners) | {new_id}))
        if not res.ok:
            raise CoordinatorError(f"add_node failed: {res.error}")
        if wait_for_promotion:
            loop = self.cluster.loop
            deadline = loop.now + max_sim_time
            while loop.now < deadline:
                ldr = self._leader()
                if new_id in ldr.config:
                    return new_id
                loop.run_until(loop.now + 0.05)
            raise CoordinatorError(f"node {new_id} was never promoted")
        return new_id

    def remove_node(self, node_id: int, retries: int = 5) -> None:
        """Remove ANY replica, the current leader included: removing the
        leader does a planned handover first (§5.1 end-lease, then step
        aside), waits for the successor, and retries the removal there."""
        for _ in range(retries):
            ldr = self._leader()
            if node_id not in ldr.config and node_id not in ldr.learners:
                return                          # already out
            if node_id == ldr.id:
                self.relinquish_leadership()    # handover, then retry below
                continue
            res = self._run(ldr.change_membership(
                set(ldr.config) - {node_id},
                learners=set(ldr.learners) - {node_id}))
            if res.ok:
                return
            self.cluster.loop.run_until(self.cluster.loop.now + 0.3)
        raise CoordinatorError(f"remove_node({node_id}) failed "
                               f"after {retries} retries")

    # legacy names for the same operations
    def scale_up(self) -> int:
        return self.add_node()

    def scale_down(self, node_id: int) -> None:
        self.remove_node(node_id)

    # -- fault injection ---------------------------------------------------
    def crash_leader(self) -> int:
        ldr = self._leader()
        ldr.crash()
        return ldr.id

    def restart_node(self, node_id: int) -> None:
        self.cluster.nodes[node_id].restart()

    def relinquish_leadership(self) -> None:
        """Planned handover (paper §5.1 end-lease)."""
        ldr = self._leader()
        ldr.relinquish_lease()
        self.cluster.loop.run_until(self.cluster.loop.now + 0.2)
        ldr.crash()

    def stats(self) -> dict:
        return {
            "consistency": self.read_mode.value,
            "reads": self.reads,
            "read_messages": self.read_messages,
            "messages_total": self.cluster.net.messages_sent,
            "leader": self.cluster.directory.leader_id,
            "term": self.cluster.directory.leader_term,
        }

"""Integration tests: coordinator registry, Raft-committed checkpoints,
deterministic checkpoint/restart, failover during training, serving."""

import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, ShapeConfig
from repro.coord.kvstore import LocalCoordinator
from repro.coord.registry import ClusterRegistry
from repro.launch.train import PRESETS, run_training
from repro.models import init_params
from repro.serve.engine import Engine, ServeConfig
from repro.train.checkpoint import (restore_checkpoint, save_checkpoint,
                                    verify_checkpoint)

TINY = ArchConfig(
    name="itest-tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, grad_accum=1,
    param_dtype="float32")
SHAPE = ShapeConfig("itest", "train", 32, 4)


# ------------------------------------------------------------ coordinator
def test_registry_checkpoint_commit_and_leased_read():
    reg = ClusterRegistry()
    assert reg.latest_checkpoint() is None
    reg.commit_checkpoint({"step": 1, "sha256": "a" * 64, "path": "x",
                           "n_arrays": 0, "extra": {}})
    reg.commit_checkpoint({"step": 2, "sha256": "b" * 64, "path": "y",
                           "n_arrays": 0, "extra": {}})
    latest = reg.latest_checkpoint()
    assert latest["step"] == 2
    stats = reg.coord.stats()
    # LeaseGuard: linearizable reads with ZERO messages
    assert stats["reads"] >= 2 and stats["read_messages"] == 0


def test_registry_survives_coordinator_failover():
    reg = ClusterRegistry()
    reg.commit_checkpoint({"step": 7, "sha256": "c" * 64, "path": "z",
                           "n_arrays": 0, "extra": {}})
    reg.coord.crash_leader()
    assert reg.latest_checkpoint()["step"] == 7      # inherited-lease read
    reg.commit_checkpoint({"step": 8, "sha256": "d" * 64, "path": "z",
                           "n_arrays": 0, "extra": {}})  # deferred commit
    assert reg.latest_checkpoint()["step"] == 8


def test_membership_and_stragglers():
    reg = ClusterRegistry()
    reg.register_worker("w0")
    reg.register_worker("w1")
    reg.deregister_worker("w0")
    assert reg.live_workers() == {"w1"}
    for step in range(6):
        reg.report_step_time("w1", step, 1.0)
        reg.report_step_time("w2", step, 5.0)
    flags = reg.straggler_flags(threshold=1.5)
    assert flags["w2"] and not flags["w1"]


def test_planned_handover_no_lease_wait():
    coord = LocalCoordinator()
    coord.append("k", 1)
    t0 = coord.cluster.loop.now
    coord.relinquish_leadership()        # end-lease entry (paper §5.1)
    coord.append("k", 2)                 # next leader commits immediately
    assert coord.read_latest("k") == 2
    assert coord.cluster.loop.now - t0 < 2.0


# ------------------------------------------------------------ checkpoints
def test_checkpoint_roundtrip_and_verify():
    state = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
             "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    d = tempfile.mkdtemp()
    try:
        manifest = save_checkpoint(d, 3, state)
        assert verify_checkpoint(manifest)
        restored = restore_checkpoint(state, manifest)
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(state["a"]))
        assert restored["b"]["c"].dtype == jnp.bfloat16
        # corruption detected
        with open(os.path.join(manifest["path"], "arrays.npz"), "ab") as f:
            f.write(b"junk")
        assert not verify_checkpoint(manifest)
    finally:
        shutil.rmtree(d)


# --------------------------------------------------------------- training
def test_train_resume_is_deterministic():
    """20 straight steps == 10 steps + checkpoint + restore + 10 steps."""
    d = tempfile.mkdtemp()
    try:
        reg1 = ClusterRegistry()
        full = run_training(TINY, SHAPE, 20, d + "/a", ckpt_every=100,
                            registry=reg1, log_every=100)
        reg2 = ClusterRegistry()
        run_training(TINY, SHAPE, 10, d + "/b", ckpt_every=10,
                     registry=reg2, log_every=100)
        resumed = run_training(TINY, SHAPE, 20, d + "/b", ckpt_every=100,
                               registry=reg2, log_every=100)
        np.testing.assert_allclose(full["losses"][10:],
                                   resumed["losses"], rtol=1e-4)
    finally:
        shutil.rmtree(d)


def test_train_through_coordinator_failover():
    d = tempfile.mkdtemp()
    try:
        reg = ClusterRegistry()
        out = run_training(TINY, SHAPE, 8, d, ckpt_every=4,
                           registry=reg, failover_at=2, log_every=100)
        assert len(out["losses"]) == 8
        assert reg.latest_checkpoint()["step"] == 8
    finally:
        shutil.rmtree(d)


# ---------------------------------------------------------------- serving
def test_engine_generates_and_reads_version():
    reg = ClusterRegistry()
    reg.commit_checkpoint({"step": 5, "sha256": "e" * 64, "path": "-",
                           "n_arrays": 0, "extra": {}})
    params = init_params(jax.random.PRNGKey(0), TINY)
    eng = Engine(TINY, params, ServeConfig(max_new_tokens=4), registry=reg)
    assert eng.model_version["step"] == 5
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 TINY.vocab_size)
    out = eng.generate(prompts)
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < TINY.vocab_size).all()


def test_greedy_generation_is_deterministic():
    params = init_params(jax.random.PRNGKey(0), TINY)
    eng = Engine(TINY, params, ServeConfig(max_new_tokens=4))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 TINY.vocab_size)
    np.testing.assert_array_equal(eng.generate(prompts),
                                  eng.generate(prompts))

"""The standing safety net: every consistent policy must stay
linearizable under every safe nemesis scenario, the inconsistent
baseline must get caught, and random fault compositions (property-based,
via the hypothesis stub fallback) must not shake out stale reads."""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fixed-example fallback
    from _hypothesis_stub import given, settings, st

from repro.consistency import REGISTRY
from repro.core import (LinearizabilityError, RaftParams, ReadMode, SimParams,
                        check_linearizability, run_workload)
from repro.faults import (build_scenario, random_scenario,
                          safe_scenario_names, unsafe_scenario_names)

CONSISTENT_MODES = [m for m in REGISTRY if m is not ReadMode.INCONSISTENT]


def nemesis_run(mode, scenario_name, seed, *, follower_frac=0.0,
                sim_duration=1.2, scenario=None):
    sc = scenario if scenario is not None else build_scenario(scenario_name)
    # scenarios may require RaftParams flags for their expect_safe
    # classification (corruption tier needs entry_checksums)
    raft = RaftParams(read_mode=mode, election_timeout=0.3,
                      election_jitter=0.1, heartbeat_interval=0.03,
                      lease_duration=0.6, rpc_timeout=0.15,
                      **sc.raft_overrides)
    sim = SimParams(seed=seed, sim_duration=sim_duration, interarrival=3e-3,
                    follower_read_fraction=follower_frac)
    return run_workload(raft, sim, fault_script=sc.install, check=False,
                        settle_time=1.5)


# ------------------------------------------------- scenario x policy matrix
@pytest.mark.parametrize("scenario_name", safe_scenario_names())
def test_leaseguard_linearizable_under_every_safe_scenario(scenario_name):
    res = nemesis_run(ReadMode.LEASEGUARD, scenario_name, seed=7)
    assert check_linearizability(res.history) > 0
    assert res.reads_ok + res.writes_ok > 0     # availability sanity


@pytest.mark.parametrize("mode", CONSISTENT_MODES,
                         ids=[m.value for m in CONSISTENT_MODES])
@pytest.mark.parametrize("scenario_name", ["leader_nemesis", "combo_chaos"])
def test_every_consistent_policy_survives_hard_scenarios(mode, scenario_name):
    """The two most adversarial safe schedules (leader-chasing nemesis;
    overlapping partition+chaos+crash) across the whole registry."""
    frac = 0.3 if mode is ReadMode.FOLLOWER_READ else 0.0
    res = nemesis_run(mode, scenario_name, seed=11, follower_frac=frac)
    assert check_linearizability(res.history) > 0


@pytest.mark.parametrize("scenario_name,seed", [
    ("delay_spike", 12), ("delay_spike", 18), ("dup_reorder", 5),
    ("io_slowdown_leader", 12),
])
def test_follower_read_linearization_point_regression(scenario_name, seed):
    """Regression: the follower-read path used to stamp reads with the
    *serve* time while serving its (lagging) local state — writes the
    leader committed between barrier and serve made the read stale. These
    (scenario, seed) cells are the ones the fault matrix first flagged;
    the fix linearizes at the leader's barrier time and cuts the value at
    the read index."""
    res = nemesis_run(ReadMode.FOLLOWER_READ, scenario_name, seed,
                      follower_frac=0.3)
    assert check_linearizability(res.history) > 0


# ------------------------------------------------------- positive control
def test_inconsistent_baseline_is_caught_under_partition():
    """The oracle must actually bite: the no-mechanism baseline serves
    stale reads under a majority/minority split, and the checker flags
    them. (Seeds from the matrix artifact; all three violate.)"""
    caught = 0
    for seed in (8, 16, 18):
        res = nemesis_run(ReadMode.INCONSISTENT, "majority_minority", seed,
                          follower_frac=0.3)
        try:
            check_linearizability(res.history)
        except LinearizabilityError:
            caught += 1
    assert caught == 3


def test_unsafe_scenarios_exist_and_run():
    """Beyond-the-fault-model schedules (lying clocks, disk loss) are
    registered, runnable, and excluded from the safe catalogue."""
    assert set(unsafe_scenario_names()) >= {"clock_lie_leader", "disk_loss"}
    for name in unsafe_scenario_names():
        res = nemesis_run(ReadMode.LEASEGUARD, name, seed=3)
        assert len(res.history) > 0   # engine expresses the fault; no crash


def test_lying_clock_scenario_produces_detected_stale_read():
    """The §4.3 breach end-to-end through the nemesis engine: a leader
    whose clock claims tight bounds while 10s slow keeps 'its' lease
    after losing a majority partition, serves a stale read, and the
    checker flags it."""
    from repro.core import ClientLogEntry, build_cluster
    from repro.faults import ClockSkew, MajorityMinority, Scenario, Window

    raft = RaftParams(read_mode=ReadMode.LEASEGUARD, election_timeout=0.3,
                      election_jitter=0.1, heartbeat_interval=0.03,
                      lease_duration=0.6)
    c = build_cluster(raft, SimParams(seed=2))
    old = c.wait_for_leader()
    run = lambda coro: c.loop.run_until_complete(c.loop.create_task(coro))

    sc = Scenario("lie", [
        Window(ClockSkew(skew=-10.0, scope="leader", lie=True), at=0.1),
        Window(MajorityMinority(leader_in_minority=True), at=0.15,
               until=3.0),
    ], expect_safe=False)
    sc.install(c)

    h = []
    t0 = c.loop.now
    w1 = run(old.client_write("x", 1))
    assert w1.ok
    h.append(ClientLogEntry("ListAppend", t0, w1.entry.execution_ts,
                            c.loop.now, "x", 1, True))
    c.loop.run_until(c.loop.now + 2.0)   # skew + partition fire; failover
    new = next(n for n in c.nodes.values() if n.is_leader() and n is not old)
    t1 = c.loop.now
    w2 = run(new.client_write("x", 2))
    assert w2.ok
    h.append(ClientLogEntry("ListAppend", t1, w2.entry.execution_ts,
                            c.loop.now, "x", 2, True))
    c.loop.run_until(c.loop.now + 0.05)
    t2 = c.loop.now
    r = run(old.client_read("x"))        # lying lease lets the stale read out
    assert r.ok and r.value == [1]
    h.append(ClientLogEntry("Read", t2, r.execution_ts, c.loop.now, "x",
                            r.value, True))
    with pytest.raises(LinearizabilityError):
        check_linearizability(h)


# ------------------------------------------------------ property tests
@given(seed=st.integers(0, 10_000))
@settings(max_examples=6, deadline=None)
def test_random_fault_schedule_keeps_leaseguard_linearizable(seed):
    """Any scenario composed from the safe fault library preserves
    linearizability for the flagship policy."""
    sc = random_scenario(seed)
    res = nemesis_run(ReadMode.LEASEGUARD, None, seed=seed % 97, scenario=sc)
    assert check_linearizability(res.history) >= 0


@given(seed=st.integers(0, 10_000),
       mode=st.sampled_from([ReadMode.QUORUM, ReadMode.READ_INDEX,
                             ReadMode.ONGARO_LEASE, ReadMode.FOLLOWER_READ]))
@settings(max_examples=6, deadline=None)
def test_random_fault_schedule_keeps_other_policies_linearizable(seed, mode):
    sc = random_scenario(seed + 31337)
    frac = 0.3 if mode is ReadMode.FOLLOWER_READ else 0.0
    res = nemesis_run(mode, None, seed=seed % 89, follower_frac=frac,
                      scenario=sc)
    assert check_linearizability(res.history) >= 0

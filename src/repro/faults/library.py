"""The fault library: every perturbation the nemesis engine can apply.

Network faults build on ``Network``'s directional cuts and
:class:`~repro.core.network.MessageFault` rules; clock faults on
``BoundedClock.set_skew`` (honest) and ``faulty`` (lying); process faults
on ``Node.crash``/``Node.restart(wipe_disk=...)``.

Victim selection goes through ``FaultContext.pick(scope)`` and is
resolved at *activation* time, so e.g. ``scope="leader"`` targets
whoever leads when the window opens — and :class:`LeaderNemesis`
re-resolves on every firing, chasing each newly elected leader.
"""

from __future__ import annotations

from dataclasses import replace
from typing import Optional

from ..core.network import MessageFault
from ..core.prob import PRNG
from ..core.raft import AppendEntries
from .base import Fault, FaultContext


# ---------------------------------------------------------------- partitions
class _PartitionFault(Fault):
    """Shared undo bookkeeping: subclasses cut directed links via
    ``_cut``; ``stop`` heals exactly what was cut."""

    def __init__(self) -> None:
        self._cuts: list[tuple[int, int]] = []

    def _cut(self, ctx: FaultContext, src: int, dst: int) -> None:
        ctx.net.partition_oneway(src, dst)
        self._cuts.append((src, dst))

    def _cut_pair(self, ctx: FaultContext, a: int, b: int) -> None:
        self._cut(ctx, a, b)
        self._cut(ctx, b, a)

    def stop(self, ctx: FaultContext) -> None:
        for src, dst in self._cuts:
            ctx.net.heal_oneway(src, dst)
        self._cuts.clear()


class IsolateLeader(_PartitionFault):
    """Cut the current leader off from everyone. ``direction``:

    * ``both`` — classic symmetric isolation;
    * ``out``  — the leader can hear but not be heard (followers miss
      heartbeats and elect; the deposed leader learns of it);
    * ``in``   — the leader can be heard but hears nothing (followers stay
      quiet, the leader cannot commit: an availability trap).
    """

    def __init__(self, direction: str = "both") -> None:
        super().__init__()
        assert direction in ("both", "in", "out"), direction
        self.direction = direction
        self.name = f"isolate_leader[{direction}]"

    def start(self, ctx: FaultContext) -> None:
        vid = ctx.leader_id()
        for other in ctx.ids():
            if other == vid:
                continue
            if self.direction in ("both", "out"):
                self._cut(ctx, vid, other)
            if self.direction in ("both", "in"):
                self._cut(ctx, other, vid)


class MajorityMinority(_PartitionFault):
    """Split the cluster into two sides; ``leader_in_minority`` puts the
    leader on the losing side (the classic failover-forcing split)."""

    def __init__(self, leader_in_minority: bool = True) -> None:
        super().__init__()
        self.leader_in_minority = leader_in_minority
        side = "minority" if leader_in_minority else "majority"
        self.name = f"majority_minority[leader_in_{side}]"

    def start(self, ctx: FaultContext) -> None:
        if self.leader_in_minority:
            minority = set(ctx.minority(with_leader=True))
        else:
            minority = set(ctx.minority(with_leader=False))
        for a in ctx.ids():
            for b in ctx.ids():
                if a < b and (a in minority) != (b in minority):
                    self._cut_pair(ctx, a, b)


class PartialPartition(_PartitionFault):
    """Cut a single follower-follower link: both endpoints still see the
    rest of the cluster (the Cloudflare-outage topology that traps naive
    Raft implementations in election loops)."""

    name = "partial_partition"

    def start(self, ctx: FaultContext) -> None:
        followers = ctx.followers()
        if len(followers) >= 2:
            self._cut_pair(ctx, followers[0], followers[1])


class OneWayLink(_PartitionFault):
    """Cut exactly one directed link between the two lowest followers."""

    name = "oneway_link"

    def start(self, ctx: FaultContext) -> None:
        followers = ctx.followers()
        if len(followers) >= 2:
            self._cut(ctx, followers[0], followers[1])


# -------------------------------------------------------------- clock faults
class ClockSkew(Fault):
    """Per-node clock skew/drift. Honest by default (bounds widen, safety
    holds, availability degrades); ``lie=True`` makes the clock claim its
    normal tight bounds while actually being off — the §4.3 fault model
    breach that forfeits linearizability."""

    def __init__(self, skew: float, drift_rate: float = 0.0,
                 scope: str = "minority", lie: bool = False) -> None:
        self.skew = skew
        self.drift_rate = drift_rate
        self.scope = scope
        self.lie = lie
        kind = "lying" if lie else "honest"
        self.name = f"clock_skew[{kind},{scope}]"
        self._victims: list[int] = []

    def start(self, ctx: FaultContext) -> None:
        self._victims = ctx.pick(self.scope)
        for nid in self._victims:
            clock = ctx.nodes[nid].clock
            if self.lie:
                clock.faulty = True
                clock.fault_skew = self.skew
            else:
                clock.set_skew(self.skew, self.drift_rate)

    def stop(self, ctx: FaultContext) -> None:
        for nid in self._victims:
            clock = ctx.nodes[nid].clock
            if self.lie:
                clock.faulty = False
                clock.fault_skew = 0.0
            else:
                clock.clear_skew()
        self._victims = []


# ------------------------------------------------------------ process faults
class CrashRestart(Fault):
    """Crash the scope's nodes, restart them ``downtime`` later. With
    ``wipe_disk`` the restart loses persistent state (term/vote/log) —
    beyond Raft's fault model, hence only in unsafe scenarios."""

    def __init__(self, scope: str = "leader", downtime: float = 0.3,
                 wipe_disk: bool = False) -> None:
        self.scope = scope
        self.downtime = downtime
        self.wipe_disk = wipe_disk
        wipe = ",wipe" if wipe_disk else ""
        self.name = f"crash_restart[{scope}{wipe}]"
        self._down: list[int] = []

    def start(self, ctx: FaultContext) -> None:
        for nid in ctx.pick(self.scope):
            node = ctx.nodes[nid]
            if not node.alive:
                continue
            node.crash()
            self._down.append(nid)
            ctx.loop.call_later(
                self.downtime, lambda n=node: self._restart(ctx, n))

    def _restart(self, ctx: FaultContext, node) -> None:
        if not node.alive:
            node.restart(wipe_disk=self.wipe_disk)
            ctx.note(f"restarted node {node.id}"
                     f"{' (disk wiped)' if self.wipe_disk else ''}")
        if node.id in self._down:
            self._down.remove(node.id)

    def stop(self, ctx: FaultContext) -> None:
        # window closes early: bring anything still down back now
        for nid in list(self._down):
            node = ctx.nodes[nid]
            if not node.alive:
                node.restart(wipe_disk=self.wipe_disk)
        self._down.clear()


class LeaderNemesis(Fault):
    """The leader-chasing nemesis: every ``period`` it checks for a leader
    of a term it has not struck yet and crash-restarts it. Because the
    victim is re-resolved per firing, each newly elected leader gets hit
    in turn — the schedule the paper's availability story must survive."""

    def __init__(self, period: float = 0.5, downtime: float = 0.25,
                 wipe_disk: bool = False) -> None:
        self.period = period
        self.downtime = downtime
        self.wipe_disk = wipe_disk
        self.name = f"leader_nemesis[p={period}]"
        self._active = False
        self._last_struck_term = -1

    def start(self, ctx: FaultContext) -> None:
        self._active = True
        self._last_struck_term = -1
        self._tick(ctx)

    def _tick(self, ctx: FaultContext) -> None:
        if not self._active:
            return
        ldr = ctx.leader()
        if ldr is not None and ldr.alive and ldr.is_leader() \
                and ldr.term > self._last_struck_term:
            self._last_struck_term = ldr.term
            ctx.note(f"nemesis strikes leader {ldr.id} (term {ldr.term})")
            ldr.crash()
            ctx.loop.call_later(
                self.downtime,
                lambda n=ldr: n.restart(wipe_disk=self.wipe_disk)
                if not n.alive else None)
        ctx.loop.call_later(self.period, lambda: self._tick(ctx))

    def stop(self, ctx: FaultContext) -> None:
        self._active = False
        for node in ctx.nodes.values():
            if not node.alive:
                node.restart(wipe_disk=self.wipe_disk)


# --------------------------------------------------------- membership faults
class MembershipChaos(Fault):
    """Scheduled membership churn through ``change_membership`` (paper
    §4.4): every ``period`` the next op from an add/remove schedule is
    attempted against the current leader. Adds spawn a fresh node that
    joins as a non-voting learner (the leader auto-promotes it once its
    match index covers the commit index); removes drop a voter follower
    and — with ``decommission`` — crash it for good. Failed attempts
    (no leader, reconfig in progress, deposed mid-append) retry on the
    next tick, so the schedule survives overlapping crash/partition
    faults."""

    def __init__(self, period: float = 0.2, adds: int = 2, removes: int = 2,
                 decommission: bool = True, victim: str = "low") -> None:
        self.period = period
        ops: list[str] = []
        for i in range(max(adds, removes)):
            if i < adds:
                ops.append("add")
            if i < removes:
                ops.append("remove")
        self.ops = ops
        self.decommission = decommission
        assert victim in ("low", "high"), victim
        self.victim = victim
        self.name = f"membership_chaos[+{adds}/-{removes}]"
        self._active = False
        self._i = 0
        self._busy = False
        self._pending = None     # spawned-but-not-yet-joined learner

    def start(self, ctx: FaultContext) -> None:
        self._active = True
        self._i = 0
        self._tick(ctx)

    def _tick(self, ctx: FaultContext) -> None:
        if not self._active or self._i >= len(self.ops):
            return
        if not self._busy:
            ctx.loop.create_task(self._act(ctx))
        ctx.loop.call_later(self.period, lambda: self._tick(ctx))

    async def _act(self, ctx: FaultContext) -> None:
        self._busy = True
        try:
            ldr = ctx.leader()
            if ldr is None or not ldr.is_leader():
                return
            if self.ops[self._i] == "add":
                if self._pending is None or not self._pending.alive:
                    new_id = max(ctx.nodes) + 1
                    self._pending = ctx.cluster.spawn_node(
                        new_id, ldr.p, learner=True)
                res = await ldr.change_membership(
                    set(ldr.config),
                    learners=set(ldr.learners) | {self._pending.id})
                if res.ok:
                    ctx.note(f"added learner {self._pending.id}")
                    self._pending = None
                    self._i += 1
            else:
                voters = sorted(v for v in ldr.config if v != ldr.id)
                if len(voters) < 2:
                    self._i += 1      # refuse to shrink below two voters
                    return
                target = voters[0] if self.victim == "low" else voters[-1]
                res = await ldr.change_membership(set(ldr.config) - {target})
                if res.ok:
                    ctx.note(f"removed voter {target}")
                    self._i += 1
                    if self.decommission:
                        gone = ctx.nodes.get(target)
                        if gone is not None and gone.alive:
                            gone.crash()
        finally:
            self._busy = False

    def stop(self, ctx: FaultContext) -> None:
        # membership changes are durable — stopping just ends the churn
        self._active = False


class DiskLossRejoin(Fault):
    """The SAFE disk-loss path (ROADMAP item): crash the scope's nodes,
    demote each to a non-voting learner in the replicated config while it
    is down, then restart it disk-wiped with ``rejoin_as_learner`` — it
    refuses votes and elections regardless of stale log prefixes, the
    leader clamps its match index on first contact, replication catches
    it up, and auto-promotion returns it to the voter set via an ordinary
    CONFIG entry. Contrast ``CrashRestart(wipe_disk=True)``, which
    restarts a wiped node as a full voter and breaks Leader
    Completeness."""

    def __init__(self, scope: str = "minority", downtime: float = 0.2,
                 repair_timeout: float = 5.0) -> None:
        self.scope = scope
        self.downtime = downtime
        self.repair_timeout = repair_timeout
        self.name = f"disk_loss_rejoin[{scope}]"

    def start(self, ctx: FaultContext) -> None:
        for nid in ctx.pick(self.scope):
            node = ctx.nodes[nid]
            if not node.alive:
                continue
            node.crash()
            ctx.note(f"crashed node {nid} (disk lost)")
            ctx.loop.create_task(self._demote(ctx, nid))
            ctx.loop.call_later(self.downtime,
                                lambda n=node: self._rejoin(ctx, n))

    async def _demote(self, ctx: FaultContext, nid: int) -> None:
        """Move the wiped node from the voter to the learner set, retrying
        across leader changes until the CONFIG entry commits."""
        deadline = ctx.loop.now + self.repair_timeout
        while ctx.loop.now < deadline:
            ldr = ctx.leader()
            if ldr is not None and ldr.is_leader():
                if nid not in ldr.config:
                    return                      # already a learner (or gone)
                res = await ldr.change_membership(
                    set(ldr.config) - {nid},
                    learners=set(ldr.learners) | {nid})
                if res.ok:
                    ctx.note(f"demoted wiped node {nid} to learner")
                    return
            await ctx.loop.sleep(0.05)

    def _rejoin(self, ctx: FaultContext, node) -> None:
        if not node.alive:
            node.restart(wipe_disk=True, rejoin_as_learner=True)
            ctx.note(f"restarted node {node.id} as wiped learner")

    def stop(self, ctx: FaultContext) -> None:
        # the repair is durable (learner demotion + auto-promotion live in
        # the replicated config); nothing to undo when the window closes
        pass


# ------------------------------------------------------------ message faults
class MessageChaos(Fault):
    """Install a :class:`MessageFault` rule for the window: extra delay,
    reorder jitter, probabilistic loss, duplication — globally or on one
    directed link."""

    def __init__(self, extra_delay: float = 0.0, jitter: float = 0.0,
                 drop_prob: float = 0.0, dup_prob: float = 0.0,
                 src: Optional[int] = None, dst: Optional[int] = None,
                 label: str = "") -> None:
        self.rule = MessageFault(extra_delay=extra_delay, jitter=jitter,
                                 drop_prob=drop_prob, dup_prob=dup_prob,
                                 src=src, dst=dst)
        self.name = f"message_chaos[{label}]" if label else "message_chaos"
        self._handle: Optional[int] = None

    def start(self, ctx: FaultContext) -> None:
        self._handle = ctx.net.add_fault(self.rule)

    def stop(self, ctx: FaultContext) -> None:
        if self._handle is not None:
            ctx.net.remove_fault(self._handle)
            self._handle = None


class SlowNode(Fault):
    """Gray failure: the scope's nodes are up but degraded — extra
    per-message I/O service time plus inflated (and jittered) latency on
    everything they send. Unlike a crash, the node keeps answering
    *eventually*, so failure detectors stay quiet while its RPCs straggle
    past ``rpc_timeout`` — the fixed-retry hot loop the adaptive backoff
    flag exists to tame."""

    def __init__(self, scope: str = "followers", extra_io: float = 500e-6,
                 send_delay: float = 0.1, send_jitter: float = 0.05) -> None:
        self.scope = scope
        self.extra_io = extra_io
        self.send_delay = send_delay
        self.send_jitter = send_jitter
        self.name = f"slow_node[{scope}]"
        self._victims: list[int] = []
        self._handles: list[int] = []

    def start(self, ctx: FaultContext) -> None:
        self._victims = ctx.pick(self.scope)
        for nid in self._victims:
            ctx.net.set_io_slowdown(nid, self.extra_io)
            self._handles.append(ctx.net.add_fault(MessageFault(
                extra_delay=self.send_delay, jitter=self.send_jitter,
                src=nid)))

    def stop(self, ctx: FaultContext) -> None:
        for nid in self._victims:
            ctx.net.set_io_slowdown(nid, 0.0)
        for h in self._handles:
            ctx.net.remove_fault(h)
        self._victims = []
        self._handles = []


class FlappingLink(Fault):
    """Gray failure: directed links flap on a deterministic duty cycle —
    cut for ``down`` seconds, healed for ``up`` seconds, repeating while
    the window is open. The default cuts every inbound link of the first
    follower: the victim intermittently goes deaf, its election timer
    fires, and (without PreVote) each flap bumps the term and evicts a
    perfectly healthy leader. ``direction="out"`` flaps the victim's
    outbound side instead; ``direction="pair"`` flaps the single directed
    link victim -> leader.

    ``flaps`` counts down-phase onsets; the property tests bound term
    inflation per flap. Victim and links are resolved once, at window
    start."""

    def __init__(self, victim_scope: str = "followers",
                 direction: str = "in",
                 up: float = 0.25, down: float = 0.2) -> None:
        assert direction in ("in", "out", "pair"), direction
        self.victim_scope = victim_scope
        self.direction = direction
        self.up = up
        self.down = down
        self.name = f"flapping_link[{victim_scope},{direction}]"
        self._active = False
        self._links: list[tuple[int, int]] = []
        self.victim: Optional[int] = None
        self.flaps = 0

    def start(self, ctx: FaultContext) -> None:
        vid = ctx.pick(self.victim_scope)[0]
        self.victim = vid
        if self.direction == "in":
            self._links = [(p, vid) for p in ctx.ids() if p != vid]
        elif self.direction == "out":
            self._links = [(vid, p) for p in ctx.ids() if p != vid]
        else:
            self._links = [(vid, ctx.leader_id())]
        self._active = True
        self.flaps = 0
        self._go_down(ctx)

    def _go_down(self, ctx: FaultContext) -> None:
        if not self._active:
            return
        self.flaps += 1
        ctx.note(f"flap down #{self.flaps} (victim {self.victim})")
        for src, dst in self._links:
            ctx.net.partition_oneway(src, dst)
        ctx.loop.call_later(self.down, lambda: self._go_up(ctx))

    def _go_up(self, ctx: FaultContext) -> None:
        if not self._active:
            return
        ctx.note(f"flap up (victim {self.victim})")
        for src, dst in self._links:
            ctx.net.heal_oneway(src, dst)
        ctx.loop.call_later(self.up, lambda: self._go_down(ctx))

    def stop(self, ctx: FaultContext) -> None:
        self._active = False
        for src, dst in self._links:
            ctx.net.heal_oneway(src, dst)


class CorruptFault(Fault):
    """Field-level corruption of in-flight AppendEntries: with
    probability ``prob`` per delivered message, one field is mutated —
    a data entry's value, ``prev_index``, ``prev_term``, or
    ``leader_commit``. Mutated messages are fresh copies (the originals
    are shared with the sender's log and must stay pristine); any stale
    checksum/digest travels with the copy, so with
    ``RaftParams.entry_checksums`` the receiver detects and drops it,
    and without checksums the corruption is *applied* — the adversarial
    positive control for the linearizability checker.

    Draws come from a private PRNG seeded by ``seed``: zero draws from
    any pre-existing stream, so scenarios without this fault replay
    bit-identically."""

    def __init__(self, prob: float = 0.05, seed: int = 0xBADC0DE,
                 src: Optional[int] = None,
                 dst: Optional[int] = None) -> None:
        self.prob = prob
        self.seed = seed
        self.src = src
        self.dst = dst
        self.name = f"corrupt_append[p={prob}]"
        self.prng = PRNG(seed)
        self.corrupted = 0
        self._handle: Optional[int] = None

    def start(self, ctx: FaultContext) -> None:
        self.prng = PRNG(self.seed)
        self._handle = ctx.net.add_interceptor(
            lambda s, d, m: self._intercept(ctx, s, d, m))

    def _intercept(self, ctx: FaultContext, src: int, dst: int, msg):
        if not isinstance(msg, AppendEntries):
            return msg
        if self.src is not None and src != self.src:
            return msg
        if self.dst is not None and dst != self.dst:
            return msg
        if self.prng.random() >= self.prob:
            return msg
        bad = replace(msg, entries=list(msg.entries))
        # payload rot weighted up: header mutations (kinds 1-3) mostly
        # bounce off Raft's log-matching check, payload rot is the silent
        # kind real checksum machinery exists for
        kind = self.prng.choice([0, 0, 0, 1, 2, 3])
        data = [i for i, e in enumerate(bad.entries) if not e.is_control]
        if kind == 0 and not data:
            kind = 3                 # heartbeat: no payload to rot
        if kind == 0:
            # bit-rot a data entry's payload: same term/key, garbage value
            # (control entries are excluded — a mangled CONFIG payload
            # models a crash, not silent corruption)
            i = self.prng.choice(data)
            e = bad.entries[i]
            bad.entries[i] = replace(e, value=f"CORRUPT:{e.value}")
        elif kind == 1:
            bad.prev_index += self.prng.choice([-2, -1, 1, 2])
        elif kind == 2:
            bad.prev_term += self.prng.choice([1, 2])
        else:
            bad.leader_commit += self.prng.choice([-1, 1, 2])
        self.corrupted += 1
        ctx.note(f"corrupted append {src}->{dst} (kind {kind})")
        return bad

    def stop(self, ctx: FaultContext) -> None:
        if self._handle is not None:
            ctx.net.remove_interceptor(self._handle)
            self._handle = None


class IoSlowdown(Fault):
    """Extra per-message I/O service time on the scope's nodes (models a
    slow disk / saturated NIC rather than a slow network)."""

    def __init__(self, extra_service_time: float = 200e-6,
                 scope: str = "leader") -> None:
        self.extra = extra_service_time
        self.scope = scope
        self.name = f"io_slowdown[{scope}]"
        self._victims: list[int] = []

    def start(self, ctx: FaultContext) -> None:
        self._victims = ctx.pick(self.scope)
        for nid in self._victims:
            ctx.net.set_io_slowdown(nid, self.extra)

    def stop(self, ctx: FaultContext) -> None:
        for nid in self._victims:
            ctx.net.set_io_slowdown(nid, 0.0)
        self._victims = []

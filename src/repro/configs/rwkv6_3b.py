"""rwkv6-3b — Finch: attention-free, data-dependent decay time-mix.
[arXiv:2404.05892; hf]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=8960,
    vocab_size=65536,
    attn_free=True,
    grad_accum=4,
    rwkv_head_dim=64,         # 2560 / 64 = 40 wkv heads
    source="arXiv:2404.05892",
)

"""Fig. 11 (and the ~1k → ~10k writes/s headline): throughput ceilings per
consistency mechanism under increasing offered load.

I/O contention is modeled by a per-node serialized message-processing
budget (``io_service_time``): quorum reads consume the same I/O as
replication, so reads and writes contend — reproducing LogCabin's
throughput collapse with quorum checks. LeaseGuard reads consume no I/O
at all, so throughput tracks the inconsistent configuration.
"""

from __future__ import annotations

from repro.consistency import benchmark_configs, split_bench_config
from repro.core import RaftParams, SimParams, run_workload


def run(quick: bool = False) -> list[dict]:
    mechanisms = benchmark_configs(variants=False)
    loads = [2000, 10000] if quick else [2000, 5000, 10000, 20000, 40000]
    rows = []
    for ops_per_s in loads:
        for name, config in mechanisms.items():
            flags, sim_flags = split_bench_config(config)
            raft = RaftParams(election_timeout=1.0, heartbeat_interval=0.1,
                              rpc_timeout=0.5, **flags)
            sim = SimParams(
                seed=11,
                io_service_time=40e-6,     # 40 µs/message/node I/O budget
                sim_duration=0.6 if quick else 1.5,
                interarrival=1.0 / ops_per_s,
                write_fraction=1 / 3,
                **sim_flags,
            )
            res = run_workload(raft, sim, check=False, settle_time=1.0)
            s = res.summarize()
            dur = sim.sim_duration
            rows.append({
                "mechanism": name,
                "offered_ops_per_s": ops_per_s,
                "achieved_ops_per_s": (res.reads_ok + res.writes_ok) / dur,
                "writes_per_s": res.writes_ok / dur,
                "reads_per_s": res.reads_ok / dur,
                "read_p90_ms": s["read_p90"] * 1e3,
                "write_p90_ms": s["write_p90"] * 1e3,
            })
    return rows

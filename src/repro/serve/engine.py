"""Batched serving engine: prefill + decode over a request batch, with
the KV-cache pytree managed per step and serving metadata (model version
= latest committed checkpoint) read from the coordinator with leased
zero-roundtrip reads."""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import decode_step, init_decode_cache, prefill


@dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0     # 0 = greedy
    seed: int = 0


class Engine:
    """Single-host batched engine (the multi-pod serve path is lowered by
    launch/dryrun.py with the production mesh; this class drives real
    arrays for the examples/tests)."""

    def __init__(self, cfg: ArchConfig, params, serve_cfg: ServeConfig =
                 ServeConfig(), registry=None,
                 consistency: Optional[str] = None) -> None:
        if registry is None and consistency is not None:
            # stand up a coordinator with the named policy from the
            # repro.consistency registry (e.g. "leaseguard", "readindex")
            from ..coord.registry import ClusterRegistry
            registry = ClusterRegistry(consistency=consistency)
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg
        self.registry = registry
        self.model_version: Optional[dict] = None
        if registry is not None:
            # leased read: which checkpoint should we be serving?
            self.model_version = registry.latest_checkpoint()
        self._decode = jax.jit(partial(decode_step, cfg=self.cfg))

    def generate(self, tokens: jax.Array,
                 max_new_tokens: Optional[int] = None) -> np.ndarray:
        """tokens: (B, S) prompt batch -> (B, new) generated ids."""
        cfg = self.cfg
        b, s = tokens.shape
        n_new = max_new_tokens or self.scfg.max_new_tokens
        logits, caches, pos = prefill(self.params, cfg, {"tokens": tokens})
        # grow KV caches to hold the generated tokens
        if not cfg.attn_free:
            def grow(c):
                if c.ndim == 5:   # (L, B, S, Hkv, hd)
                    pad = [(0, 0)] * 5
                    pad[2] = (0, n_new)
                    return jnp.pad(c, pad)
                return c
            caches = jax.tree.map(grow, caches)
        out = []
        key = jax.random.PRNGKey(self.scfg.seed)
        tok = self._sample(logits, key)
        out.append(tok)
        for i in range(n_new - 1):
            logits, caches = decode_step(self.params, cfg, tok, caches,
                                         pos + i)
            key = jax.random.fold_in(key, i)
            tok = self._sample(logits, key)
            out.append(tok)
        return np.stack([np.asarray(t) for t in out], axis=1)

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1).astype(jnp.int32)

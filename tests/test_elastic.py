"""Elastic scaling of the coordinator through the public API."""

from repro.coord.kvstore import LocalCoordinator


def test_coordinator_scale_up_down():
    coord = LocalCoordinator()
    coord.append("k", 1)
    new_id = coord.scale_up()
    assert coord.read_latest("k") == 1
    coord.append("k", 2)
    ldr = coord._leader()
    assert new_id in ldr.config and len(ldr.config) == 4
    # scale back down (pick a non-leader member)
    victim = next(i for i in ldr.config if i not in (ldr.id,))
    coord.scale_down(victim)
    assert len(coord._leader().config) == 3
    assert coord.read_latest("k") == 2


def test_scaled_up_cluster_tolerates_extra_failure():
    coord = LocalCoordinator()
    coord.append("k", 1)
    coord.scale_up()
    coord.scale_up()                       # now 5 nodes: tolerates 2 faults
    ldr = coord._leader()
    assert len(ldr.config) == 5
    followers = [n for n in coord.cluster.nodes.values()
                 if n.alive and n is not ldr][:2]
    for f in followers:
        f.crash()
    coord.append("k", 2)
    assert coord.read_latest("k") == 2

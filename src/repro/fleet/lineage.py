"""Checkpoint lineage: the fleet log schema, epoch fencing, and the
omniscient post-run checker.

The whole fleet coordinates through ONE replicated key (``fleet/log``)
holding two record kinds, both appended through the Raft log:

* ``{"kind": "claim", "epoch": e, "chief": wid}`` — a worker claiming
  chiefdom for a new epoch;
* ``{"kind": "manifest", "epoch": e, "chief": wid, "step": s,
  "parent": p, "id": "wid:e:s"}`` — a checkpoint manifest committed by
  a chief.

**Epoch fencing.** A manifest is *valid* iff its ``(epoch, chief)``
equals the nearest *preceding* claim in the log (first occurrence per
``id`` wins). Because claims and manifests share one key, fencing is
decided by Raft's own total order — no timestamps involved: the moment
a new chief's claim commits, every later manifest by the deposed chief
is invalid by construction. A new chief appends its claim and *then*
performs its takeover read, so under a linearizable read policy that
read observes every valid manifest that will ever precede its claim —
which is exactly what makes valid steps monotone for consistent
policies and lets stale reads (the ``inconsistent`` policy) break them.

**The checker** is omniscient in the same way ``core.checker`` is: it
reads the surviving replicas' Raft log directly (record + the entry's
``execution_ts``, the true commit-on-leader time) and the harness's
restore trace, and asserts:

1. **no forks** — valid manifests have strictly increasing steps;
2. **durability** — every manifest a worker restored from is in the
   committed log with ``execution_ts`` no later than the read's return;
3. **staleness bound** — no restore observed less than the newest valid
   manifest committed strictly before the read began (a linearizable
   read must see every write that committed before it started).
"""

from __future__ import annotations

import json
from typing import Any, Optional

FLEET_KEY = "fleet/log"

_EPS = 1e-9


class LogView:
    """Incremental fold of the fleet log. Feeding a longer raw list only
    decodes the new tail — the log is append-only and committed prefixes
    of equal length are identical (Raft log matching), so the fold state
    is monotone. Feeding a *shorter* list than already seen is a stale
    read; callers detect that via :attr:`n` before feeding."""

    __slots__ = ("n", "_cur", "last_claim", "valid", "_seen")

    def __init__(self) -> None:
        self.n = 0
        self._cur: Optional[tuple] = None       # fence: (epoch, chief)
        self.last_claim: Optional[dict] = None
        self.valid: list[dict] = []             # fenced, deduped manifests
        self._seen: set[str] = set()

    def feed_raw(self, raw: list) -> "LogView":
        for v in raw[self.n:]:
            self.feed_one(json.loads(v))
        self.n = len(raw)
        return self

    def feed_one(self, rec: dict) -> None:
        kind = rec.get("kind")
        if kind == "claim":
            self._cur = (rec["epoch"], rec["chief"])
            self.last_claim = rec
        elif kind == "manifest":
            if (self._cur == (rec["epoch"], rec["chief"])
                    and rec["id"] not in self._seen):
                self._seen.add(rec["id"])
                self.valid.append(rec)

    @property
    def latest(self) -> Optional[dict]:
        return self.valid[-1] if self.valid else None


def extract_fleet_log(cluster, key: str = FLEET_KEY) -> list[tuple[dict, Optional[float]]]:
    """The committed fleet log as ``(record, execution_ts)`` pairs, read
    omnisciently off the most advanced surviving replica's Raft log.
    ``execution_ts`` is the commit-on-leader time (None for the rare
    entry applied on a follower whose leader never got to stamp it)."""
    node = max(cluster.nodes.values(),
               key=lambda n: (n.alive, n.last_applied, -n.id))
    out = []
    for idx in range(1, node.last_applied + 1):
        e = node.log[idx]
        if e.key == key:
            out.append((json.loads(e.value), e.execution_ts))
    return out


def check_lineage(entries: list[tuple[dict, Optional[float]]],
                  restores: list[dict]) -> list[dict]:
    """Run the three lineage checks; returns a list of violation dicts
    (empty = clean). ``restores`` is the harness trace: each has ``wid``,
    ``kind`` (boot / rejoin / takeover), ``t_start``/``t_end`` of the
    read, and ``manifest`` (the valid manifest it observed, or None)."""
    violations: list[dict] = []

    fence: Optional[tuple] = None
    seen: set[str] = set()
    valid: list[tuple[dict, Optional[float]]] = []
    committed_ts: dict[str, Optional[float]] = {}
    for rec, ts in entries:
        kind = rec.get("kind")
        if kind == "claim":
            fence = (rec["epoch"], rec["chief"])
        elif kind == "manifest":
            if rec["id"] not in committed_ts:
                committed_ts[rec["id"]] = ts
            if fence == (rec["epoch"], rec["chief"]) and rec["id"] not in seen:
                seen.add(rec["id"])
                valid.append((rec, ts))

    # 1. committed steps monotone, no forks
    prev: Optional[dict] = None
    for rec, ts in valid:
        if prev is not None and rec["step"] <= prev["step"]:
            violations.append({
                "check": "fork", "id": rec["id"], "epoch": rec["epoch"],
                "chief": rec["chief"], "step": rec["step"],
                "prev_step": prev["step"],
                "detail": "valid manifest steps went non-monotone"})
        prev = rec

    for r in restores:
        man = r["manifest"]
        # 2. durability: you can only restore from a committed manifest,
        #    and only after it committed
        if man is not None:
            ts = committed_ts.get(man["id"], "missing")
            if ts == "missing":
                violations.append({
                    "check": "durability", "wid": r["wid"],
                    "kind": r["kind"], "id": man["id"],
                    "detail": "restored manifest never committed"})
            elif ts is not None and ts > r["t_end"] + _EPS:
                violations.append({
                    "check": "durability", "wid": r["wid"],
                    "kind": r["kind"], "id": man["id"],
                    "detail": "restored manifest committed after the read "
                              "returned"})
        # 3. staleness: a linearizable read beginning at t_start must see
        #    every valid manifest committed strictly before t_start
        bound, bound_id = -1, None
        for rec, ts in valid:
            if ts is not None and ts < r["t_start"] - _EPS \
                    and rec["step"] > bound:
                bound, bound_id = rec["step"], rec["id"]
        observed = man["step"] if man is not None else -1
        if observed < bound:
            violations.append({
                "check": "stale_restore", "wid": r["wid"], "kind": r["kind"],
                "observed_step": observed, "bound_step": bound,
                "bound_id": bound_id,
                "detail": "restored from a manifest staler than the "
                          "policy's consistency bound"})
    return violations

"""Linearizability: checker unit tests, adversarial mutations of
known-good histories (the oracle must catch every planted violation),
the §4.3 faulty-clock violation, and hypothesis property tests over
random schedules and fault scripts."""

import dataclasses

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fixed-example fallback
    from _hypothesis_stub import given, settings, st

from repro.core import (ClientLogEntry, LinearizabilityError, RaftParams,
                        ReadMode, SimParams, build_cluster,
                        check_linearizability, run_workload)


def op(kind, start, exc, end, key, value, ok=True):
    return ClientLogEntry(kind, start, exc, end, key, value, ok)


# ----------------------------------------------------------- checker units
def test_checker_accepts_valid_history():
    h = [
        op("ListAppend", 0.0, 0.1, 0.2, "k", 1),
        op("Read", 0.3, 0.35, 0.4, "k", [1]),
        op("ListAppend", 0.5, 0.6, 0.7, "k", 2),
        op("Read", 0.8, 0.85, 0.9, "k", [1, 2]),
    ]
    assert check_linearizability(h) == 4


def test_checker_catches_stale_read():
    h = [
        op("ListAppend", 0.0, 0.1, 0.2, "k", 1),
        op("Read", 0.3, 0.35, 0.4, "k", []),    # stale: misses committed 1
    ]
    with pytest.raises(LinearizabilityError):
        check_linearizability(h)


def test_checker_catches_read_from_the_future():
    h = [
        op("ListAppend", 0.5, 0.6, 0.7, "k", 1),
        op("Read", 0.0, 0.1, 0.2, "k", [1]),    # observes a later write
    ]
    with pytest.raises(LinearizabilityError):
        check_linearizability(h)


def test_checker_catches_execution_outside_invocation_window():
    h = [op("Read", 0.3, 0.9, 0.4, "k", [])]
    with pytest.raises(LinearizabilityError):
        check_linearizability(h)


def test_checker_failed_append_observed_only_if_committed():
    # failed at client but has a commit time -> effect may be observed
    h = [
        op("ListAppend", 0.0, 0.3, 0.2, "k", 1, ok=False),
        op("Read", 0.4, 0.5, 0.6, "k", [1]),
    ]
    assert check_linearizability(h) == 2
    # failed with NO commit time -> must never be observed
    h2 = [
        op("ListAppend", 0.0, None, 0.2, "k", 1, ok=False),
        op("Read", 0.4, 0.5, 0.6, "k", [1]),
    ]
    with pytest.raises(LinearizabilityError):
        check_linearizability(h2)


def test_checker_tie_groups():
    # two appends + a read at the same instant: some interleaving must work
    h = [
        op("ListAppend", 0.0, 0.5, 0.9, "k", 1),
        op("ListAppend", 0.0, 0.5, 0.9, "k", 2),
        op("Read", 0.0, 0.5, 0.9, "k", [1]),
    ]
    assert check_linearizability(h) == 3
    # read observing a value no tied append provides -> violation
    h2 = [
        op("ListAppend", 0.0, 0.5, 0.9, "k", 1),
        op("Read", 0.0, 0.5, 0.9, "k", [2]),
    ]
    with pytest.raises(LinearizabilityError):
        check_linearizability(h2)


# ---------------------------------------------- adversarial checker tests
# Mutate a real, checker-clean history in targeted ways and require the
# oracle to flag every planted violation — proof the safety net is not
# vacuously green.
@pytest.fixture(scope="module")
def clean_history():
    raft = RaftParams(election_timeout=0.3, election_jitter=0.1,
                      heartbeat_interval=0.03, lease_duration=0.6)
    sim = SimParams(seed=23, sim_duration=0.8, interarrival=2e-3)
    res = run_workload(raft, sim, check=True, settle_time=1.0)
    assert res.linearizable_ops > 50
    return res.history


def _pick_observing_read(history):
    """A successful read that observed >= 1 append and shares no execution
    timestamp with any append to its key (avoids tie-group leniency)."""
    for r in history:
        if r.op_type == "Read" and r.success and r.value:
            append_ts = {a.execution_ts for a in history
                         if a.op_type == "ListAppend" and a.key == r.key}
            if r.execution_ts not in append_ts:
                return r
    raise AssertionError("no suitable read in history")


def test_mutation_dropped_append_is_caught(clean_history):
    """Remove an append some read observed: the read now sees a value the
    linearization cannot explain."""
    r = _pick_observing_read(clean_history)
    victim = r.value[-1]
    mutated = [op for op in clean_history
               if not (op.op_type == "ListAppend" and op.key == r.key
                       and op.value == victim)]
    assert len(mutated) == len(clean_history) - 1
    with pytest.raises(LinearizabilityError):
        check_linearizability(mutated)


def test_mutation_staled_read_is_caught(clean_history):
    """Truncate a read's observed list: it now misses an append committed
    before its linearization point."""
    r = _pick_observing_read(clean_history)
    stale = dataclasses.replace(r, value=list(r.value[:-1]))
    mutated = [stale if op is r else op for op in clean_history]
    with pytest.raises(LinearizabilityError):
        check_linearizability(mutated)


def test_mutation_append_exec_after_response_is_caught(clean_history):
    """Shift a successful append's execution_ts past its response time."""
    a = next(op for op in clean_history
             if op.op_type == "ListAppend" and op.success)
    shifted = dataclasses.replace(a, execution_ts=a.end_ts + 0.5)
    mutated = [shifted if op is a else op for op in clean_history]
    with pytest.raises(LinearizabilityError):
        check_linearizability(mutated)


def test_mutation_read_exec_before_invocation_is_caught(clean_history):
    """Shift a successful read's execution_ts before its invocation."""
    r = next(op for op in clean_history
             if op.op_type == "Read" and op.success)
    shifted = dataclasses.replace(r, execution_ts=r.start_ts - 0.5)
    mutated = [shifted if op is r else op for op in clean_history]
    with pytest.raises(LinearizabilityError):
        check_linearizability(mutated)


def test_mutation_failed_append_given_early_commit_is_caught(clean_history):
    """Give some append a commit time before its invocation (a 'write from
    the past'): the omniscient rule must reject it."""
    a = next(op for op in clean_history
             if op.op_type == "ListAppend" and op.success)
    forged = dataclasses.replace(a, success=False,
                                 execution_ts=a.start_ts - 1.0)
    mutated = [forged if op is a else op for op in clean_history]
    with pytest.raises(LinearizabilityError):
        check_linearizability(mutated)


# ------------------------------------------------- §4.3 faulty clock demo
def test_faulty_clock_causes_stale_read_caught_by_checker():
    """Inherited lease reads REQUIRE correct clock bounds (paper §4.3).
    A deposed leader whose clock interval is wrong keeps 'its' lease while
    the new leader commits — the checker sees the stale read."""
    c = build_cluster(RaftParams(lease_duration=1.0, election_timeout=0.5),
                      SimParams())
    loop = c.loop
    ldr = c.wait_for_leader()
    run = lambda coro: loop.run_until_complete(loop.create_task(coro))

    h = []
    t0 = loop.now
    w1 = run(ldr.client_write("x", 1))
    assert w1.ok
    h.append(ClientLogEntry("ListAppend", t0, w1.entry.execution_ts,
                            loop.now, "x", 1, True))
    # break the old leader's clock: it now claims intervals 10s in the past,
    # so its lease never looks expired to itself
    ldr.clock.faulty = True
    ldr.clock.fault_skew = -10.0
    for o in c.nodes.values():
        if o is not ldr:
            c.net.partition(ldr.id, o.id)
    loop.run_until(loop.now + 4.0)     # new leader elected; real lease expired
    new = next(n for n in c.nodes.values() if n.is_leader() and n is not ldr)
    t1 = loop.now
    w2 = run(new.client_write("x", 2))
    assert w2.ok
    h.append(ClientLogEntry("ListAppend", t1, w2.entry.execution_ts,
                            loop.now, "x", 2, True))
    loop.run_until(loop.now + 0.05)    # read strictly after the new write
    # stale read on the deposed leader: with a correct clock this returns
    # no_lease (test_leaseguard), with the faulty clock it "succeeds"
    t2 = loop.now
    r = run(ldr.client_read("x"))
    assert r.ok and r.value == [1], "faulty clock should allow the stale read"
    h.append(ClientLogEntry("Read", t2, r.execution_ts, loop.now, "x",
                            r.value, True))
    with pytest.raises(LinearizabilityError):
        check_linearizability(h)


# ------------------------------------------------------ property tests
MODES = [
    dict(read_mode=ReadMode.LEASEGUARD),
    dict(read_mode=ReadMode.LEASEGUARD, defer_commit_writes=False,
         inherited_lease_reads=False),
    dict(read_mode=ReadMode.LEASEGUARD, lease_duration=1.0),
    dict(read_mode=ReadMode.QUORUM),
]


@given(seed=st.integers(0, 10_000), mode=st.sampled_from(range(len(MODES))),
       crash_t=st.floats(0.1, 0.8))
@settings(max_examples=20, deadline=None)
def test_linearizable_under_leader_crash(seed, mode, crash_t):
    raft = RaftParams(election_timeout=0.3, election_jitter=0.1,
                      heartbeat_interval=0.03, **MODES[mode])
    sim = SimParams(seed=seed, sim_duration=1.2, interarrival=2e-3)

    def script(cluster):
        def crash():
            ldr = cluster.leader()
            if ldr is not None and ldr.alive:
                ldr.crash()
        cluster.loop.call_later(crash_t, crash)

    res = run_workload(raft, sim, fault_script=script, check=True,
                       settle_time=2.0)
    assert res.linearizable_ops > 0
    # some work must eventually succeed (availability sanity)
    assert res.reads_ok + res.writes_ok > 0


@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_linearizable_under_partition_and_heal(seed):
    raft = RaftParams(election_timeout=0.3, election_jitter=0.1,
                      heartbeat_interval=0.03, lease_duration=0.6)
    sim = SimParams(seed=seed, sim_duration=1.5, interarrival=2e-3)

    def script(cluster):
        def part():
            ldr = cluster.leader()
            if ldr is None:
                return
            for o in cluster.nodes.values():
                if o is not ldr:
                    cluster.net.partition(ldr.id, o.id)
        cluster.loop.call_later(0.3, part)
        cluster.loop.call_later(0.9, lambda: cluster.net.heal())

    res = run_workload(raft, sim, fault_script=script, check=True,
                       settle_time=2.0)
    assert res.linearizable_ops > 0


@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_leader_completeness_property(seed):
    """Every committed entry is in every later leader's log."""
    raft = RaftParams(election_timeout=0.3, election_jitter=0.1,
                      heartbeat_interval=0.03)
    sim = SimParams(seed=seed, sim_duration=1.0, interarrival=3e-3)
    c = build_cluster(raft, sim)
    ldr = c.wait_for_leader()
    from repro.core.client import Workload
    w = Workload(c.loop, c.nodes, c.directory, c.prng.fork(999), sim)
    c.loop.create_task(w.run(sim.sim_duration))
    c.loop.call_later(0.4, lambda: c.leader() and c.leader().crash())
    c.loop.run_until(c.loop.now + sim.sim_duration + 2.0)
    leaders = [n for n in c.nodes.values() if n.is_leader()]
    if not leaders:
        return
    final = leaders[0]
    keys_in_final = {(e.term, e.key, e.value) for e in final.log}
    for rec, entry in w._entry_refs:
        if entry.execution_ts is not None:     # committed somewhere
            assert (entry.term, entry.key, entry.value) in keys_in_final


@given(seed=st.integers(0, 10_000),
       clock_error=st.sampled_from([1e-6, 50e-6, 1e-3, 10e-3]))
@settings(max_examples=12, deadline=None)
def test_linearizable_across_clock_error_magnitudes(seed, clock_error):
    """Correct (bounded) clocks of ANY precision preserve safety — larger
    error only costs availability at the lease boundary (paper §4.3)."""
    raft = RaftParams(election_timeout=0.3, election_jitter=0.1,
                      heartbeat_interval=0.03, lease_duration=0.5,
                      max_clock_error=clock_error)
    sim = SimParams(seed=seed, sim_duration=1.2, interarrival=2e-3)

    def script(cluster):
        cluster.loop.call_later(
            0.4, lambda: cluster.leader() and cluster.leader().crash())

    res = run_workload(raft, sim, fault_script=script, check=True,
                       settle_time=2.0)
    assert res.linearizable_ops > 0


@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_ongaro_lease_linearizable_under_crash(seed):
    """The comparison baseline must be safe too (it delays elections
    instead of gating commits)."""
    raft = RaftParams(read_mode=ReadMode.ONGARO_LEASE, election_timeout=0.3,
                      election_jitter=0.1, heartbeat_interval=0.03)
    sim = SimParams(seed=seed, sim_duration=1.2, interarrival=2e-3)

    def script(cluster):
        cluster.loop.call_later(
            0.4, lambda: cluster.leader() and cluster.leader().crash())

    res = run_workload(raft, sim, fault_script=script, check=True,
                       settle_time=2.0)
    assert res.linearizable_ops > 0


@given(seed=st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_linearizable_with_reconfiguration_mid_run(seed):
    """Membership changes during a live workload preserve linearizability
    (paper §4.4)."""
    raft = RaftParams(election_timeout=0.3, election_jitter=0.1,
                      heartbeat_interval=0.03, lease_duration=0.5)
    sim = SimParams(seed=seed, sim_duration=1.2, interarrival=2e-3)

    def script(cluster):
        def scale():
            ldr = cluster.leader()
            if ldr is None or not ldr.alive:
                return
            node = cluster.spawn_node(max(cluster.nodes) + 1, raft)
            cluster.loop.create_task(
                ldr.change_membership(set(ldr.config) | {node.id}))
        cluster.loop.call_later(0.3, scale)
        cluster.loop.call_later(
            0.7, lambda: cluster.leader() and cluster.leader().crash())

    res = run_workload(raft, sim, fault_script=script, check=True,
                       settle_time=2.5)
    assert res.linearizable_ops > 0

"""The training-worker actor: an async task on the *simulated* event
loop, sharing it with the Raft replica set it coordinates through.

Each worker models one data-parallel trainer (parameter-server style —
workers step at their own pace; there is no lockstep barrier):

* register + heartbeat through :class:`~repro.coord.registry.AsyncClusterRegistry`;
* restore from the latest **valid** checkpoint manifest before training
  (boot, rejoin after a crash, and chief takeover all restore — these
  reads are the lineage-critical ones the checker audits);
* every step: launch a non-blocking poll of the fleet log via the
  configured read policy (training never blocks on the control plane —
  the paper's point is that under LeaseGuard this per-step poll is free,
  while under quorum reads it is a cluster-wide message storm), train
  for ``step_time`` (jittered, times any straggler slowdown), report
  step times on a cadence;
* watch the chief: the lowest-indexed live worker claims chiefdom for
  ``epoch+1`` when the claimed chief falls out of the membership TTL.
  A claim is an ordinary fleet-log append; the claimant then *reads
  back* — the read both confirms the claim won (last claim is ours) and
  doubles as the takeover restore. The chief commits a manifest every
  ``ckpt_every`` of its own steps.

Crash/restart is modelled by a generation counter: data-plane faults
flip ``alive`` and bump ``generation``; in-flight tasks notice at their
next await and die. Restart spawns fresh tasks with the next generation
— and, like a real trainer losing local state, the worker re-registers
and restores from the registry before training again.
"""

from __future__ import annotations

from typing import Optional

from ..coord.kvstore import CoordClient
from ..coord.registry import AsyncClusterRegistry
from .lineage import FLEET_KEY, LogView


class Worker:
    def __init__(self, fleet, index: int, prng, client: CoordClient) -> None:
        self.fleet = fleet
        self.index = index
        self.wid = f"w{index}"
        self.prng = prng
        self.client = client
        self.registry = AsyncClusterRegistry(client)
        self.alive = False
        self.generation = 0
        self.slowdown = 1.0                 # straggler faults scale this
        self.local_step = 0
        self.observed_step = -1             # newest valid step this worker saw
        self.is_chief = False
        self.epoch = 0
        self.view = LogView()
        self._last_committed_step = -1
        self._last_hb = float("-inf")
        self._poll_inflight = False
        # counters
        self.steps = 0
        self.polls_ok = 0
        self.polls_failed = 0
        self.stale_polls = 0
        self.commits_ok = 0
        self.commits_failed = 0

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> None:
        self.generation += 1
        self.alive = True
        self.slowdown = 1.0
        self.local_step = 0
        self.observed_step = -1
        self.is_chief = False
        self.view = LogView()
        self._last_committed_step = -1
        self._last_hb = float("-inf")
        self._poll_inflight = False
        gen = self.generation
        loop = self.fleet.loop
        loop.create_task(self._run(gen))
        loop.create_task(self._chief_watch(gen))

    def crash(self) -> None:
        self.alive = False
        self.is_chief = False
        self.generation += 1                # kills in-flight tasks

    def _ok(self, gen: int) -> bool:
        return self.alive and self.generation == gen

    @property
    def loop(self):
        return self.fleet.loop

    @property
    def p(self):
        return self.fleet.p

    # -- main loop ---------------------------------------------------------
    async def _run(self, gen: int) -> None:
        p = self.p
        kind = "boot" if self.loop.now <= self.fleet.t0 + 1e-9 else "rejoin"
        while self._ok(gen) and self.fleet.running:
            if await self.registry.register_worker(self.wid):
                break
            await self.loop.sleep(p.retry_delay)
        # a worker cannot train before it has a checkpoint to train from
        while self._ok(gen) and self.fleet.running:
            if await self._restore(gen, kind):
                break
            await self.loop.sleep(p.retry_delay)
        while self._ok(gen) and self.fleet.running:
            now = self.loop.now
            if now - self._last_hb >= p.heartbeat_period:
                self._last_hb = now
                self.loop.create_task(self._heartbeat(gen))
            if not self._poll_inflight:
                self._poll_inflight = True
                self.loop.create_task(self._poll(gen))
            dt = (p.step_time * (1.0 + p.step_jitter * self.prng.random())
                  * self.slowdown)
            await self.loop.sleep(dt)
            if not self._ok(gen):
                break
            self.local_step += 1
            self.steps += 1
            self.fleet.total_steps += 1
            if self.steps % p.report_every == 0:
                self.loop.create_task(self._report(gen, dt))
            if (self.is_chief and self.local_step - self._last_committed_step
                    >= self.fleet.ckpt_every()):
                await self._commit(gen)

    async def _heartbeat(self, gen: int) -> None:
        if self._ok(gen):
            await self.registry.heartbeat(self.wid)

    async def _report(self, gen: int, dt: float) -> None:
        if self._ok(gen):
            await self.registry.report_step_time(self.wid, self.local_step, dt)

    # -- reads -------------------------------------------------------------
    async def _restore(self, gen: int, kind: str) -> bool:
        t_start = self.loop.now
        res = await self.client.read_raw(FLEET_KEY, timeout=self.p.op_timeout)
        if not self._ok(gen) or not res.ok:
            return False
        view = LogView().feed_raw(res.value)    # exactly what THIS read saw
        man = view.latest
        self.fleet.record_restore(self.wid, kind, t_start, self.loop.now,
                                  man, gen)
        self.view = view
        self.local_step = man["step"] if man else 0
        self.observed_step = man["step"] if man else -1
        return True

    async def _poll(self, gen: int) -> None:
        """Per-step checkpoint poll — fire-and-forget so training never
        blocks on the control plane; at most one in flight per worker."""
        try:
            res = await self.client.read_raw(FLEET_KEY,
                                             timeout=self.p.poll_timeout)
            if not self._ok(gen):
                return
            if not res.ok:
                self.polls_failed += 1
                return
            self.polls_ok += 1
            if len(res.value) < self.view.n:
                self.stale_polls += 1       # saw less than we already did
                return
            self.view.feed_raw(res.value)
            man = self.view.latest
            if man is not None and man["step"] > self.observed_step:
                self.observed_step = man["step"]
        finally:
            self._poll_inflight = False

    # -- chief election & checkpointing ------------------------------------
    async def _chief_watch(self, gen: int) -> None:
        p = self.p
        # deterministic stagger: workers don't all probe at once
        await self.loop.sleep(0.5 * p.chief_check_period
                              + 0.03 * (self.index + 1))
        while self._ok(gen) and self.fleet.running:
            await self._chief_tick(gen)
            if not self._ok(gen):
                return
            await self.loop.sleep(p.chief_check_period)

    async def _chief_tick(self, gen: int) -> None:
        p = self.p
        res = await self.client.read_raw(FLEET_KEY, timeout=p.op_timeout)
        if not self._ok(gen) or not res.ok:
            return
        if len(res.value) < self.view.n:
            # a stale view — under the inconsistent policy we knowingly
            # act on it anyway; that is the hazard the positive control
            # exists to expose
            self.stale_polls += 1
            view = LogView().feed_raw(res.value)
        else:
            view = self.view.feed_raw(res.value)
        claim = view.last_claim
        if self.is_chief and (claim is None or claim["chief"] != self.wid
                              or claim["epoch"] != self.epoch):
            self.is_chief = False           # deposed by a newer claim
            tr = self.loop.tracer
            if tr is not None:
                tr.emit("fleet", op="deposed", wid=self.wid)
            self.fleet.note(f"chief {self.wid} deposed")
        if claim is not None and claim["chief"] == self.wid:
            if not self.is_chief:
                # the log still names us (e.g. we crashed and rejoined):
                # resume chiefdom, but only through a fresh takeover read
                await self._become_chief(gen, claim["epoch"])
            return
        live = await self.registry.live_workers(ttl=p.worker_ttl)
        if not self._ok(gen) or live is None:
            return
        chief_live = claim is not None and claim["chief"] in live
        if chief_live or not live:
            return
        cand = min(live, key=self.fleet.worker_order)
        if cand != self.wid:
            return
        epoch = (claim["epoch"] if claim is not None else 0) + 1
        await self.client.append(
            FLEET_KEY, {"kind": "claim", "epoch": epoch, "chief": self.wid,
                        "t": self.loop.now}, timeout=p.op_timeout)
        if not self._ok(gen):
            return
        # the read-back decides, whatever the append reported (an
        # ambiguous append may well have committed)
        await self._become_chief(gen, epoch)

    async def _become_chief(self, gen: int, epoch: int) -> None:
        """Confirm the last claim is ours AND restore from the same read
        — skipping this takeover restore is exactly how a resuming chief
        would fork the lineage."""
        t_start = self.loop.now
        res = await self.client.read_raw(FLEET_KEY, timeout=self.p.op_timeout)
        if not self._ok(gen) or not res.ok:
            return
        view = LogView().feed_raw(res.value)
        claim = view.last_claim
        if claim is None or claim["chief"] != self.wid \
                or claim["epoch"] != epoch:
            return                          # somebody else won the claim
        man = view.latest
        self.fleet.record_restore(self.wid, "takeover", t_start,
                                  self.loop.now, man, gen)
        step = man["step"] if man else -1
        self.local_step = max(self.local_step, step if step >= 0 else 0)
        self.observed_step = max(self.observed_step, step)
        self._last_committed_step = step
        self.epoch = epoch
        self.is_chief = True
        tr = self.loop.tracer
        if tr is not None:
            tr.emit("fleet", op="claim", wid=self.wid, epoch=epoch)
        self.fleet.note(f"chief {self.wid} claims epoch {epoch}")

    async def _commit(self, gen: int) -> None:
        step = self.local_step
        man = {"kind": "manifest", "epoch": self.epoch, "chief": self.wid,
               "step": step,
               "parent": max(self._last_committed_step, self.observed_step),
               "id": f"{self.wid}:{self.epoch}:{step}", "t": self.loop.now}
        res = await self.client.append(FLEET_KEY, man,
                                       timeout=self.p.op_timeout)
        if not self._ok(gen):
            return
        if res.ok:
            self.commits_ok += 1
            self._last_committed_step = step
            if step > self.observed_step:
                self.observed_step = step
            self.fleet.record_commit(self.loop.now, step, True)
        else:
            # ambiguous or failed: never retry the same id blindly — the
            # next poll / chief tick reveals whether it landed, and the
            # next manifest supersedes it either way
            self.commits_failed += 1
            self.fleet.record_commit(self.loop.now, step, False)

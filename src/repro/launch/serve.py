"""Serving driver: batched generation with coordinator-backed model
version discovery (leased zero-roundtrip reads).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --preset tiny --requests 4
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke
"""

from __future__ import annotations

import argparse

import jax

from ..configs import get_arch
from ..coord.registry import ClusterRegistry
from ..models import init_params
from ..serve.engine import Engine, ServeConfig
from .train import PRESETS


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="tiny")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    from ..consistency import benchmark_configs
    ap.add_argument("--consistency", default="leaseguard",
                    choices=sorted(benchmark_configs(variants=False)),
                    help="coordination read policy for model-version reads")
    args = ap.parse_args()

    if args.arch:
        cfg = get_arch(args.arch)
        if args.smoke:
            cfg = cfg.reduced()
    else:
        cfg = PRESETS[args.preset]

    registry = ClusterRegistry(consistency=args.consistency)
    registry.commit_checkpoint({"step": 0, "path": "(fresh init)",
                                "sha256": "0" * 64, "n_arrays": 0,
                                "extra": {"arch": cfg.name}})
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params,
                    ServeConfig(max_new_tokens=args.max_new,
                                temperature=args.temperature),
                    registry=registry)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.requests, args.prompt_len), 0,
        cfg.vocab_size)
    out = engine.generate(prompts)
    print(f"served {args.requests} requests, generated {out.shape[1]} "
          f"tokens each; coordinator stats: {registry.coord.stats()}")


if __name__ == "__main__":
    main()

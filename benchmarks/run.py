"""Benchmark harness: one module per paper table/figure.

Usage:
    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig7]

Prints one CSV block per figure, plus a final ``name,us_per_call,derived``
summary line per benchmark for harness compatibility.
"""

from __future__ import annotations

import argparse
import json
import math
import sys
import time
from pathlib import Path

from repro.consistency import benchmark_configs, split_bench_config
from repro.core import RaftParams, SimParams, run_workload

from . import (fault_matrix, fig5_lease_duration, fig6_latency,
               fig7_availability, fig8_skewness, fig11_scalability,
               fleet_matrix, gray_matrix, simperf)
from .common import emit

MATRIX_SEED = 42
MATRIX_PATH = Path(__file__).resolve().parent.parent / \
    "BENCH_consistency_matrix.json"


def consistency_matrix(quick: bool = False) -> list[dict]:
    """Fixed-seed sweep over the whole policy registry: success counts and
    latency percentiles per policy. Written to BENCH_consistency_matrix.json
    at the repo root as the machine-readable perf-trajectory artifact."""
    rows = []
    for name, config in benchmark_configs().items():
        flags, sim_flags = split_bench_config(config)
        raft = RaftParams(election_timeout=0.5, election_jitter=0.1,
                          heartbeat_interval=0.05, lease_duration=1.0,
                          **flags)
        sim = SimParams(seed=MATRIX_SEED,
                        sim_duration=1.0 if quick else 2.0,
                        interarrival=1e-3, write_fraction=1 / 3,
                        **sim_flags)
        res = run_workload(raft, sim, check=not quick, settle_time=1.0)
        s = res.summarize()

        def us(x):  # JSON has no NaN
            return None if math.isnan(x) else round(x * 1e6, 3)

        rows.append({
            "policy": name,
            "reads_ok": res.reads_ok, "reads_fail": res.reads_fail,
            "writes_ok": res.writes_ok, "writes_fail": res.writes_fail,
            "read_p50_us": us(s["read_p50"]), "read_p90_us": us(s["read_p90"]),
            "write_p50_us": us(s["write_p50"]),
            "write_p90_us": us(s["write_p90"]),
        })
    return rows


def run_consistency_matrix(quick: bool = False) -> list[dict]:
    rows = consistency_matrix(quick=quick)
    MATRIX_PATH.write_text(json.dumps(
        {"seed": MATRIX_SEED, "quick": quick, "rows": rows}, indent=2) + "\n")
    print(f"# wrote {MATRIX_PATH}", file=sys.stderr)
    return rows


FIGS = {
    "fig5_lease_duration": fig5_lease_duration.run,
    "fig6_latency": fig6_latency.run,
    "fig7_availability": fig7_availability.run,
    "fig7_headline": fig7_availability.summarize_post_election_reads,
    "fig8_skewness": fig8_skewness.run,
    "fig11_scalability": fig11_scalability.run,
    "consistency_matrix": run_consistency_matrix,
    # policy x scenario x seed nemesis sweep -> BENCH_fault_matrix.json
    # (--quick runs the CI smoke slice)
    "fault_matrix": fault_matrix.run,
    # resilience-variant x gray/corruption scenario sweep ->
    # BENCH_gray_matrix.json (--quick runs the CI smoke slice)
    "gray_matrix": gray_matrix.run,
    # policy x fleet-scenario x seed checkpoint-lineage sweep + scale
    # sweep -> BENCH_fleet_matrix.json (--quick runs the CI smoke slice)
    "fleet_matrix": fleet_matrix.run,
    # simulator wall-time baseline -> BENCH_simperf.json
    # (--quick runs the smoke slice and checks for >30% regression)
    "simperf": simperf.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--roofline", action="store_true",
                    help="also run the data-plane roofline benchmark "
                         "(slow: compiles dry-run cells)")
    args = ap.parse_args()

    summary = []
    for name, fn in FIGS.items():
        if args.only and args.only not in name:
            continue
        print(f"\n== {name} ==", flush=True)
        t0 = time.time()
        rows = fn(quick=args.quick)
        dt = time.time() - t0
        emit(rows)
        summary.append((name, dt * 1e6 / max(1, len(rows)), len(rows)))

    if args.roofline:
        from . import roofline_bench
        print("\n== roofline ==", flush=True)
        t0 = time.time()
        rows = roofline_bench.run(quick=args.quick)
        dt = time.time() - t0
        emit(rows)
        summary.append(("roofline", dt * 1e6 / max(1, len(rows)), len(rows)))

    print("\nname,us_per_call,derived")
    for name, us, n in summary:
        print(f"{name},{us:.1f},rows={n}")


if __name__ == "__main__":
    main()

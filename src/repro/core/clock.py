"""Bounded-uncertainty clocks (paper §2.2) and drift-bounded timers (§5.3).

``intervalNow()`` returns ``[earliest, latest]`` guaranteed to contain true
time for at least one moment during the call. The simulation knows true time
(the event loop clock) and perturbs it by per-call bounded errors, modeling
AWS TimeSync / clock-bound style interval clocks (<= ``max_clock_error``).

The two LeaseGuard age checks (paper §4.3):

* a node **knows** ``t1`` is *more than Δ old* iff
  ``t1.latest + Δ < intervalNow().earliest``    (commit gate — aggressive side)
* a lease holder may read only while its entry is **not possibly** more than
  Δ old: ``intervalNow().latest <= t1.latest + Δ``  (read gate — conservative
  side)

At any true moment at most one of the two can hold (earliest <= T <= latest),
which is exactly the disjointness the Case-2 proof needs.
"""

from __future__ import annotations

from dataclasses import dataclass

from .prob import PRNG
from .simulate import EventLoop


@dataclass(frozen=True)
class TimeInterval:
    earliest: float
    latest: float

    def __post_init__(self) -> None:
        assert self.earliest <= self.latest


class BoundedClock:
    """Per-node interval clock with bounded, randomized uncertainty."""

    def __init__(self, loop: EventLoop, prng: PRNG, max_error: float,
                 faulty: bool = False, fault_skew: float = 0.0) -> None:
        self.loop = loop
        self.prng = prng
        self.max_error = max_error
        # ``faulty`` models a clock whose *claimed* bounds are wrong — used by
        # tests to demonstrate the paper's §4.3 caveat (linearizability is
        # forfeit if the interval does not contain true time).
        self.faulty = faulty
        self.fault_skew = fault_skew

    def interval_now(self) -> TimeInterval:
        t = self.loop.now
        if self.faulty:
            t = t + self.fault_skew  # true time now OUTSIDE claimed bounds
        lo = self.prng.uniform(0.0, self.max_error)
        hi = self.prng.uniform(0.0, self.max_error)
        return TimeInterval(t - lo, t + hi)

    # -- the two asymmetric age checks ------------------------------------
    def definitely_older_than(self, t1: TimeInterval, delta: float) -> bool:
        """Commit gate: provably more than ``delta`` old."""
        return t1.latest + delta < self.interval_now().earliest

    def possibly_older_than(self, t1: TimeInterval, delta: float) -> bool:
        """Read gate: NOT safe to read iff possibly more than ``delta`` old."""
        return self.interval_now().latest > t1.latest + delta

    def lease_valid(self, t1: TimeInterval, delta: float) -> bool:
        return not self.possibly_older_than(t1, delta)

"""Trace exporters: JSONL dumps and Chrome ``trace_event`` JSON.

JSONL is the canonical on-disk format: one header line (schema name +
version + free-form run metadata) followed by one event per line, each
serialized with sorted keys and minimal separators — so the same seed
always produces a byte-identical file (the determinism contract
``tests/test_obs.py`` enforces).

:func:`to_chrome_trace` converts a trace to the Chrome ``trace_event``
format (the JSON-array flavor) so any run opens directly in Perfetto
(https://ui.perfetto.dev) or ``chrome://tracing``: one "process" per
node (threads: roles / leases / reads / writes / barriers), plus a
faults process and a fleet process. Durations are reconstructed from
the event stream — leadership spans from role transitions, lease
windows from acquire/extend events, read spans from their recorded
stalls, fault windows from start/stop pairs.
"""

from __future__ import annotations

import json
from typing import Optional

from .metrics import leader_timeline
from .schema import header

_US = 1e6                       # trace_event timestamps are microseconds
_FAULT_PID = 1000
_FLEET_PID = 1001
_TIDS = {"role": 0, "lease": 1, "read": 2, "write": 3, "barrier": 4,
         "protocol": 5}


def dumps_event(e: dict) -> str:
    return json.dumps(e, sort_keys=True, separators=(",", ":"))


def write_jsonl(events: list, path, **meta) -> None:
    """Write header + events; byte-identical for identical traces."""
    with open(path, "w", encoding="utf-8") as fh:
        fh.write(dumps_event(header(**meta)) + "\n")
        for e in events:
            fh.write(dumps_event(e) + "\n")


def read_jsonl(path) -> tuple[dict, list]:
    """(header, events) from a JSONL trace file."""
    with open(path, "r", encoding="utf-8") as fh:
        head = json.loads(fh.readline())
        events = [json.loads(line) for line in fh if line.strip()]
    return head, events


def _instant(name: str, t: float, pid: int, tid: int,
             args: Optional[dict] = None) -> dict:
    ev = {"ph": "i", "name": name, "ts": round(t * _US, 3),
          "pid": pid, "tid": tid, "s": "t"}
    if args:
        ev["args"] = args
    return ev


def _span(name: str, t0: float, t1: float, pid: int, tid: int,
          args: Optional[dict] = None) -> dict:
    ev = {"ph": "X", "name": name, "ts": round(t0 * _US, 3),
          "dur": round(max(0.0, t1 - t0) * _US, 3), "pid": pid, "tid": tid}
    if args:
        ev["args"] = args
    return ev


def to_chrome_trace(events: list, t_end: Optional[float] = None) -> dict:
    out: list[dict] = []
    nodes = sorted({e["node"] for e in events if e["node"] is not None})
    for nid in nodes:
        out.append({"ph": "M", "name": "process_name", "pid": nid,
                    "args": {"name": f"node {nid}"}})
        for tname, tid in _TIDS.items():
            out.append({"ph": "M", "name": "thread_name", "pid": nid,
                        "tid": tid, "args": {"name": tname}})
    out.append({"ph": "M", "name": "process_name", "pid": _FAULT_PID,
                "args": {"name": "faults"}})
    out.append({"ph": "M", "name": "process_name", "pid": _FLEET_PID,
                "args": {"name": "fleet"}})

    last_t = events[-1]["t"] if events else 0.0
    end = last_t if t_end is None else t_end

    # leadership spans
    for s in leader_timeline(events, t_end=end):
        out.append(_span(f"leader term {s['term']}", s["t0"], s["t1"],
                         s["node"], _TIDS["role"], {"term": s["term"]}))

    # merged lease windows per (node, term)
    lease: dict[tuple, list] = {}
    for e in events:
        if e["type"] == "lease" and e["op"] in ("acquire", "extend"):
            key = (e["node"], e["term"], e["entry_term"])
            w = lease.get(key)
            if w is None or e["t"] > w[1]:      # disjoint: new window
                lease[key] = w = [e["t"], e["until"]]
            else:
                w[1] = max(w[1], e["until"])
    for (nid, term, entry_term), (t0, t1) in sorted(lease.items()):
        kind = "lease" if entry_term == term else "inherited lease"
        out.append(_span(f"{kind} t{term}", t0, min(t1, end + 1.0), nid,
                         _TIDS["lease"], {"term": term,
                                          "entry_term": entry_term,
                                          "until": t1}))

    # fault windows (start/stop pairs by label; unpaired start -> to end)
    open_faults: dict[str, float] = {}
    for e in events:
        if e["type"] != "fault":
            continue
        if e["op"] == "start":
            open_faults.setdefault(e["label"], e["t"])
        elif e["op"] == "stop":
            t0 = open_faults.pop(e["label"], None)
            if t0 is not None:
                out.append(_span(e["label"], t0, e["t"], _FAULT_PID, 0))
            else:
                out.append(_instant(f"stop {e['label']}", e["t"],
                                    _FAULT_PID, 0))
        else:
            out.append(_instant(e["label"], e["t"], _FAULT_PID, 0))
    for label, t0 in sorted(open_faults.items()):
        out.append(_span(label, t0, end, _FAULT_PID, 0))

    for e in events:
        etype, nid = e["type"], e["node"]
        if etype == "read" and e["op"] in ("done", "fail"):
            name = "read" if e["op"] == "done" else f"read:{e['error']}"
            out.append(_span(name, e["t"] - e["stall"], e["t"], nid,
                             _TIDS["read"], {"key": e["key"]}))
        elif etype == "write" and e["op"] in ("done", "fail"):
            name = "write" if e["op"] == "done" else \
                f"write:{e.get('error', '?')}"
            out.append(_instant(name, e["t"], nid, _TIDS["write"],
                                {"key": e["key"]}))
        elif etype == "barrier" and e["op"] in ("ok", "fail"):
            out.append(_instant(f"barrier:{e['op']}", e["t"], nid,
                                _TIDS["barrier"]))
        elif etype in ("role", "term_bump", "election", "vote", "commit"):
            if etype == "role":
                name = f"{e['role']} ({e['reason']})"
            elif etype == "term_bump":
                name = f"term {e['prev']}->{e['term']}"
            elif etype == "election":
                name = f"{e['kind']} t{e['term']}"
            elif etype == "vote":
                name = (f"{'pre' if e['prevote'] else ''}vote "
                        f"{'granted' if e['granted'] else 'denied'} "
                        f"-> {e['candidate']}")
            else:
                name = f"commit {e['index']}"
            out.append(_instant(name, e["t"], nid, _TIDS["protocol"],
                                {"term": e["term"]}))
        elif etype == "lease" and e["op"] in ("relinquish", "gate_blocked"):
            out.append(_instant(f"lease {e['op']}", e["t"], nid,
                                _TIDS["lease"], {"term": e["term"]}))
        elif etype == "fleet":
            if e["op"] == "note":
                name = e["label"]
            elif e["op"] == "manifest":
                name = (f"manifest step {e['step']} "
                        f"{'ok' if e['ok'] else 'failed'}")
            elif e["op"] == "restore":
                name = f"restore {e['wid']} ({e['kind']})"
            elif e["op"] == "claim":
                name = f"chief {e['wid']} epoch {e['epoch']}"
            else:
                name = f"chief {e['wid']} deposed"
            out.append(_instant(name, e["t"], _FLEET_PID, 0))

    return {"traceEvents": out, "displayTimeUnit": "ms",
            "otherData": {"format": "repro.obs chrome export"}}


def write_chrome_trace(events: list, path,
                       t_end: Optional[float] = None) -> None:
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(to_chrome_trace(events, t_end=t_end), fh, sort_keys=True)

"""Follower reads routed through the leader's lease state.

The leader runs full LeaseGuard (this class subclasses
:class:`LeaseGuardPolicy`, so leader-local reads, the commit gate and
lease upkeep are unchanged). A *follower* serves a read locally after
one light RPC to the leader:

1. follower -> leader: ``ReadIndexRequest(key)``;
2. the leader validates its lease for that key — the same zero-roundtrip
   barrier it would apply to a local read, including the §3.3 limbo
   check — and returns ``readIndex = commitIndex`` plus the barrier
   timestamp;
3. the follower waits until ``lastApplied >= readIndex`` and serves the
   state **as of readIndex**, linearized at the barrier time.

Linearizable because any write committed before the read was issued has
index <= the leader's commitIndex at barrier time (the lease rules out a
newer leader having committed past it), and the follower only answers
once it has applied at least that far. Compared with serving every read
on the leader this trades one cheap RPC for moving the read data path —
state-machine access and the value transfer — off the leader.

Two details matter for the linearization point (the nemesis matrix
caught both as real stale-read bugs):

* the follower must NOT serve its *current* applied state stamped with
  the *serve* time: the leader may have committed more entries between
  the barrier and the serve, so claiming a serve-time linearization
  point orders those committed writes before a read that cannot see
  them. The read linearizes at the **barrier** — every write committed
  before the barrier has index <= readIndex, every later commit has a
  later timestamp;
* symmetrically, the value must be cut at readIndex even if the
  follower has already applied further entries, or the read would
  observe writes from after its own linearization point.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

from ..core.raft import ReadResult
from ..core.simulate import TimeoutError_, wait_for
from .leaseguard import LeaseGuardPolicy


@dataclass
class ReadIndexRequest:
    term: int
    key: str


@dataclass
class ReadIndexReply:
    term: int
    ok: bool
    read_index: int = 0
    barrier_ts: float = 0.0     # leader time at barrier = linearization point
    error: str = ""


class FollowerReadPolicy(LeaseGuardPolicy):
    name = "follower_read"

    @classmethod
    def bench_variants(cls) -> dict[str, dict]:
        # one row (the LeaseGuard ablations belong to the parent policy);
        # route a slice of workload reads to followers so the benchmark
        # actually exercises the read-index RPC path, not just the
        # inherited leader path
        return {cls.name: {"sim_params": {"follower_read_fraction": 0.3}}}

    # ------------------------------------------------------- leader side
    def on_message(self, src: int, msg: Any) -> Any:
        if isinstance(msg, ReadIndexRequest):
            n = self.node
            if msg.term > n.term:
                n._step_down(msg.term)
                return ReadIndexReply(n.term, False, error="not_leader")
            if not n.is_leader():
                return ReadIndexReply(n.term, False, error="not_leader")
            err = self._read_barrier(msg.key)
            if err:
                return ReadIndexReply(n.term, False, error=err)
            return ReadIndexReply(n.term, True, read_index=n.commit_index,
                                  barrier_ts=n.loop.now)
        return None

    # ----------------------------------------------------- follower side
    async def gate_read(self, key: str) -> ReadResult:
        n = self.node
        if n.is_leader():
            return await super().gate_read(key)
        lid = n.leader_hint
        if lid is None or lid == n.id:
            return ReadResult(False, error="not_leader")
        try:
            reply: ReadIndexReply = await wait_for(
                n.net.call(n.id, lid, ReadIndexRequest(n.term, key)),
                n.p.rpc_timeout)
        except TimeoutError_:
            return ReadResult(False, error="timeout")
        if reply is None or not isinstance(reply, ReadIndexReply):
            return ReadResult(False, error="no_reply")
        if reply.term > n.term:
            n._step_down(reply.term)
        if not reply.ok:
            return ReadResult(False, error=reply.error)
        # serve the state AS OF the read index, linearized at the barrier
        return await self._serve_when_applied(
            key, reply.read_index, as_of_index=True,
            execution_ts=reply.barrier_ts)

"""The Tracer: a flight recorder for the deterministic simulator.

One :class:`Tracer` attaches to one :class:`~repro.core.simulate.EventLoop`
(``loop.tracer``). Every instrumentation site in the simulator follows the
same contract:

* **default-off**: the site costs one attribute load + ``is not None``
  check when tracing is disabled — no allocation, no draw, no branch into
  tracer code. Untraced runs replay bit-identically to a build without
  the tracer at all.
* **draw-order-neutral when enabled**: ``emit`` only appends to a Python
  list. It never touches a PRNG, never schedules loop callbacks, never
  mutates simulation state — so a traced run produces the exact same
  history as the untraced run of the same seed.

Events are plain dicts (directly JSON-serializable) with six reserved
keys stamped by ``emit``:

* ``id`` — 1-based emission-order id (deterministic per seed),
* ``t`` — simulated time of emission,
* ``type`` — one of :data:`~repro.obs.schema.EVENT_TYPES`,
* ``node`` — emitting node id (``None`` for fault/fleet-level events),
* ``term`` — the emitting node's Raft term at emission,
* ``parent`` — causal parent event id (``None`` for roots).

plus per-type payload fields (see :mod:`repro.obs.schema`). The causal
parent convention: each node carries ``_trace_ctx``, the id of its latest
role-transition event; everything the node does (reads, writes, lease
transitions, commits, votes) parents to that leadership/followership
context, and role events chain to the previous role event — so walking
``parent`` links from a failed read reaches the exact election (and, via
time-window joins on fault events, the exact partition) that caused it.
"""

from __future__ import annotations

from typing import Optional


class Tracer:
    """Typed, schema-versioned event recorder (see module docstring)."""

    __slots__ = ("loop", "events", "_next_id")

    def __init__(self, loop=None) -> None:
        self.loop = loop
        self.events: list[dict] = []
        self._next_id = 0
        if loop is not None:
            loop.tracer = self

    def attach(self, loop) -> "Tracer":
        self.loop = loop
        loop.tracer = self
        return self

    def detach(self) -> None:
        if self.loop is not None:
            self.loop.tracer = None
            self.loop = None

    def emit(self, etype: str, node: Optional[int] = None,
             term: Optional[int] = None, parent: Optional[int] = None,
             **fields) -> int:
        """Record one event; returns its id (for use as a causal parent).

        Must stay allocation-cheap and side-effect-free w.r.t. the
        simulation: callers pass only already-computed values.
        """
        self._next_id += 1
        e = {"id": self._next_id, "t": self.loop.now, "type": etype,
             "node": node, "term": term, "parent": parent}
        if fields:
            e.update(fields)
        self.events.append(e)
        return self._next_id

    def __len__(self) -> int:
        return len(self.events)

"""ReadIndex: Raft's batched read barrier (Ongaro's dissertation §6.4).

Like quorum reads, the leader proves it is still leader with an empty
AppendEntries round before serving — but the proof is *shared*: the
leader records ``readIndex = commitIndex`` at read arrival, and every
read that arrives while a confirmation round is pending joins the next
round instead of starting its own. A burst of N concurrent reads costs
O(1) rounds instead of N, which is the whole advantage over QUORUM on
read-heavy workloads.

Safety detail: a read may only rely on a round that *started at or
after* the read arrived — an older in-flight round cannot rule out a
depose that happened just before this read. Late arrivals therefore
wait out the stale round and share the fresh one that follows.
"""

from __future__ import annotations

from typing import Optional

from ..core.raft import ReadResult
from ..core.simulate import Future
from .base import ConsistencyPolicy


class ReadIndexPolicy(ConsistencyPolicy):
    name = "readindex"

    def __init__(self, node) -> None:
        super().__init__(node)
        # in-flight / last-finished confirmation: (started_at, done-future)
        self._round: Optional[tuple[float, Future]] = None

    def on_become_leader(self) -> None:
        self._round = None

    async def _confirmed_after(self, arrival: float) -> bool:
        """True once a leadership round that started at/after ``arrival``
        succeeded; batches concurrent callers onto one round."""
        n = self.node
        while True:
            rnd = self._round
            if rnd is not None and rnd[0] >= arrival:
                if rnd[1].done():
                    return rnd[1].result()
                return await rnd[1]
            if rnd is not None and not rnd[1].done():
                # a round from before our arrival is in flight: wait it out,
                # then share the fresh round one of the waiters starts
                await rnd[1]
                continue
            done = Future(n.loop)
            self._round = (n.loop.now, done)
            ok = await self._confirm_leadership()
            done.set_result(ok)
            return ok

    async def gate_read(self, key: str) -> ReadResult:
        n = self.node
        if not n.is_leader():
            return ReadResult(False, error="not_leader")
        term0 = n.term
        # dissertation §6.4 step 1: commitIndex only covers every acked
        # write once an own-term entry (the election no-op) has committed —
        # a fresh leader's commitIndex may lag writes the old leader acked.
        deadline = n.loop.now + n.p.read_timeout
        while n.is_leader() and n.term == term0 and \
                n.log[n.commit_index].term != n.term:
            if n.loop.now >= deadline:
                return ReadResult(False, error="timeout")
            await n._cond_wait(deadline)
        if not n.is_leader() or n.term != term0:
            return ReadResult(False, error="not_leader")
        read_index = n.commit_index  # the ReadIndex
        if not await self._confirmed_after(n.loop.now):
            return ReadResult(False, error="no_quorum")
        if not n.is_leader() or n.term != term0:
            return ReadResult(False, error="not_leader")
        return await self._serve_when_applied(key, read_index,
                                              leader_term=term0)

"""pixtral-12b — Pixtral-ViT + mistral-nemo decoder backbone. The vision
frontend is a STUB: input_specs() provides precomputed patch embeddings.
[hf:mistralai/Pixtral-12B-2409; unverified]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    embedding_stub=True,      # patch embeddings supplied by the frontend stub
    grad_accum=8,    # f32 patch-embed inputs + d=5120 stash: fits HBM at 8
    source="hf:mistralai/Pixtral-12B-2409",
)

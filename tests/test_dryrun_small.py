"""Dry-run integration on a small host-device mesh (subprocess: jax locks
device count at first init, so the 8-device XLA flag must be set before
import). One reduced arch per family × all three step kinds, plus the
sharding-spec construction for every full-size arch."""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import jax
from repro.configs import ARCHS, get_arch
from repro.configs.base import ShapeConfig
from repro.launch.dryrun import lower_cell, input_specs
from repro.sharding.rules import param_specs, state_specs
from functools import partial
from repro.models import init_params
from repro.train.optimizer import OptConfig
from repro.train.train_step import init_train_state

mesh = jax.make_mesh((2, 4), ("data", "model"))

# 1) spec construction for every FULL config (no compile)
for name, cfg in ARCHS.items():
    shapes = jax.eval_shape(partial(init_params, jax.random.PRNGKey(0), cfg))
    specs = param_specs(shapes, mesh)
    n = len(jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "index")))
    assert n > 0, name

# 2) compile one reduced cell per family x kind
fams = {}
for name, cfg in ARCHS.items():
    fams.setdefault(cfg.family, name)
results = {}
for fam, name in sorted(fams.items()):
    cfg = get_arch(name).reduced()
    cfg = dataclasses.replace(cfg, grad_accum=2)
    for kind, shape in [("train", ShapeConfig("t", "train", 64, 8)),
                        ("prefill", ShapeConfig("p", "prefill", 64, 8)),
                        ("decode", ShapeConfig("d", "decode", 64, 8))]:
        lowered = lower_cell(cfg, shape, mesh)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):   # older jax: one dict per device
            cost = cost[0] if cost else {}
        assert cost.get("flops", 0) >= 0
        results[f"{fam}:{kind}"] = True
print("DRYRUN_OK " + json.dumps(results))
"""


@pytest.mark.slow
def test_small_mesh_dryrun_all_families():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert "DRYRUN_OK" in out.stdout, f"stdout:\n{out.stdout[-2000:]}\n" \
                                      f"stderr:\n{out.stderr[-3000:]}"
    line = [l for l in out.stdout.splitlines() if l.startswith("DRYRUN_OK")][0]
    results = json.loads(line.split(" ", 1)[1])
    # 6 families x 3 kinds
    assert len(results) == 18

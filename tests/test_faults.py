"""Unit tests for the nemesis engine: directional partitions, message
fault knobs, clock skew/drift honesty, crash-restart (with and without
disk loss), victim selection, the scenario registry, and determinism of
scheduled fault runs."""

import pytest

from repro.core import (EventLoop, NetParams, Network, RaftParams, ReadMode,
                        SimParams, build_cluster, run_workload)
from repro.core.clock import BoundedClock
from repro.core.network import MessageFault
from repro.core.prob import PRNG
from repro.faults import (SCENARIOS, CrashRestart, FaultContext, IsolateLeader,
                          LeaderNemesis, MajorityMinority, MessageChaos,
                          PartialPartition, Scenario, Window, build_scenario,
                          random_scenario, safe_scenario_names,
                          unsafe_scenario_names)


# ------------------------------------------------------------- net helpers
def make_net(**params):
    loop = EventLoop()
    net = Network(loop, PRNG(1), NetParams(**params))
    inbox = {0: [], 1: [], 2: []}
    for i in inbox:
        net.register(i, lambda src, msg, i=i: inbox[i].append((src, msg)))
    return loop, net, inbox


# ------------------------------------------------- directional partitions
def test_oneway_partition_blocks_one_direction_only():
    loop, net, inbox = make_net()
    net.partition_oneway(0, 1)
    net.send(0, 1, "a")   # blocked
    net.send(1, 0, "b")   # still flows
    loop.run(max_time=1.0)
    assert inbox[1] == []
    assert inbox[0] == [(1, "b")]


def test_symmetric_partition_blocks_both_and_heals():
    loop, net, inbox = make_net()
    net.partition(0, 1)
    assert not net.reachable(0, 1) and not net.reachable(1, 0)
    net.heal(0, 1)
    assert net.reachable(0, 1) and net.reachable(1, 0)
    net.partition_oneway(0, 1)
    net.heal()            # clears directional cuts too
    assert net.reachable(0, 1)


def test_heal_oneway_leaves_other_direction_cut():
    loop, net, _ = make_net()
    net.partition(0, 1)
    net.heal_oneway(0, 1)
    assert net.reachable(0, 1)
    assert not net.reachable(1, 0)


# ------------------------------------------------------ message fault knobs
def test_drop_fault_loses_messages():
    loop, net, inbox = make_net()
    h = net.add_fault(MessageFault(drop_prob=1.0))
    net.send(0, 1, "lost")
    loop.run(max_time=1.0)
    assert inbox[1] == []
    net.remove_fault(h)
    net.send(0, 1, "found")
    loop.run(max_time=2.0)
    assert inbox[1] == [(0, "found")]


def test_dup_fault_duplicates_messages():
    loop, net, inbox = make_net()
    net.add_fault(MessageFault(dup_prob=1.0))
    net.send(0, 1, "twice")
    loop.run(max_time=1.0)
    assert inbox[1] == [(0, "twice"), (0, "twice")]


def test_extra_delay_shifts_delivery():
    loop, net, inbox = make_net()
    net.add_fault(MessageFault(extra_delay=0.5))
    net.send(0, 1, "slow")
    loop.run(max_time=0.4)
    assert inbox[1] == []
    loop.run(max_time=2.0)
    assert inbox[1] == [(0, "slow")]


def test_jitter_reorders_messages():
    loop, net, inbox = make_net()
    net.add_fault(MessageFault(jitter=0.05))
    for i in range(40):
        net.send(0, 1, i)
    loop.run(max_time=1.0)
    got = [m for _, m in inbox[1]]
    assert sorted(got) == list(range(40))   # nothing lost
    assert got != sorted(got)               # ...but order scrambled


def test_link_scoped_fault_only_hits_matching_direction():
    loop, net, inbox = make_net()
    net.add_fault(MessageFault(drop_prob=1.0, src=0, dst=1))
    net.send(0, 1, "dead-link")
    net.send(1, 0, "reverse-ok")
    net.send(0, 2, "other-dst-ok")
    loop.run(max_time=1.0)
    assert inbox[1] == []
    assert inbox[0] == [(1, "reverse-ok")]
    assert inbox[2] == [(0, "other-dst-ok")]


def test_io_slowdown_serializes_extra_service_time():
    loop, net, inbox = make_net(one_way_latency_mean=1e-9,
                                one_way_latency_variance=1e-20)
    net.set_io_slowdown(0, 0.1)
    t0 = loop.now
    for i in range(3):
        net.send(0, 1, i)
    loop.run(max_time=10.0)
    # three messages serialized through a 0.1s-per-message queue
    assert loop.now - t0 >= 0.29
    net.set_io_slowdown(0, 0.0)
    assert net._io_slow == {}


# --------------------------------------------------------------- clock faults
def test_honest_skew_keeps_true_time_in_bounds():
    loop = EventLoop()
    loop.now = 5.0
    clock = BoundedClock(loop, PRNG(3), max_error=50e-6)
    for skew in (-0.5, -0.01, 0.0, 0.01, 0.5):
        clock.set_skew(skew)
        for _ in range(20):
            iv = clock.interval_now()
            assert iv.earliest <= loop.now <= iv.latest, (skew, iv)
    clock.clear_skew()
    assert clock.skew == 0.0 and clock.drift_rate == 0.0


def test_honest_drift_accumulates_and_stays_honest():
    loop = EventLoop()
    clock = BoundedClock(loop, PRNG(3), max_error=50e-6)
    clock.set_skew(0.0, drift_rate=0.1)
    loop.now = 2.0   # 0.2s of accumulated drift
    iv = clock.interval_now()
    assert iv.earliest <= loop.now <= iv.latest
    assert iv.latest >= loop.now + 0.2 - 1e-9   # perceived time covered too


def test_lying_clock_escapes_bounds():
    loop = EventLoop()
    loop.now = 5.0
    clock = BoundedClock(loop, PRNG(3), max_error=50e-6,
                         faulty=True, fault_skew=-1.0)
    iv = clock.interval_now()
    assert iv.latest < loop.now   # true time OUTSIDE the claimed interval


# ------------------------------------------------------------ crash / restart
def test_restart_with_disk_loss_wipes_persistent_state():
    c = build_cluster(RaftParams(), SimParams())
    ldr = c.wait_for_leader()
    run = lambda coro: c.loop.run_until_complete(c.loop.create_task(coro))
    assert run(ldr.client_write("k", 1)).ok
    follower = next(n for n in c.nodes.values() if n is not ldr)
    c.loop.run_until(c.loop.now + 0.2)
    assert follower.last_log_index > 0 and follower.term > 0
    follower.crash()
    follower.restart(wipe_disk=True)
    assert follower.term == 0
    assert follower.voted_for is None
    assert follower.last_log_index == 0
    # ...and it re-replicates the log from the leader
    c.loop.run_until(c.loop.now + 0.5)
    assert follower.last_log_index > 0


def test_restart_without_wipe_keeps_log():
    c = build_cluster(RaftParams(), SimParams())
    ldr = c.wait_for_leader()
    run = lambda coro: c.loop.run_until_complete(c.loop.create_task(coro))
    assert run(ldr.client_write("k", 1)).ok
    follower = next(n for n in c.nodes.values() if n is not ldr)
    c.loop.run_until(c.loop.now + 0.2)
    idx, term = follower.last_log_index, follower.term
    follower.crash()
    follower.restart()
    assert follower.last_log_index == idx and follower.term == term


def test_rapid_crash_restart_does_not_stack_election_timers():
    """Each crash/restart bumps the timer generation, so a node that
    bounces faster than its election timeout still runs exactly one
    timer task (stacked timers caused spurious elections)."""
    c = build_cluster(RaftParams(), SimParams())
    ldr = c.wait_for_leader()
    follower = next(n for n in c.nodes.values() if n is not ldr)
    gen0 = follower._timer_gen
    for _ in range(5):
        follower.crash()
        follower.restart()
    assert follower._timer_gen == gen0 + 10
    term_before = max(n.term for n in c.nodes.values())
    c.loop.run_until(c.loop.now + 2.0)
    # a healthy cluster with one bounced follower must not churn terms
    assert max(n.term for n in c.nodes.values()) == term_before


# ----------------------------------------------------------- victim selection
def test_fault_context_victim_scopes():
    c = build_cluster(RaftParams(n_nodes=5), SimParams())
    ldr = c.wait_for_leader()
    ctx = FaultContext(c)
    assert ctx.leader_id() == ldr.id
    assert ctx.pick("leader") == [ldr.id]
    assert ldr.id not in ctx.pick("followers")
    assert len(ctx.pick("minority")) == 2
    minority_with_leader = ctx.pick("minority+leader")
    assert minority_with_leader[0] == ldr.id and len(minority_with_leader) == 2
    assert ctx.pick("all") == sorted(c.nodes)
    with pytest.raises(ValueError):
        ctx.pick("everyone")


# ------------------------------------------------------------------ scenarios
def test_registry_has_rich_safe_catalogue():
    assert len(safe_scenario_names()) >= 8
    assert len(unsafe_scenario_names()) >= 2
    assert set(safe_scenario_names()) | set(unsafe_scenario_names()) \
        == set(SCENARIOS)


def test_every_scenario_builds_fresh_instances():
    for name in SCENARIOS:
        a, b = build_scenario(name), build_scenario(name)
        assert a.name == b.name == name
        assert a is not b
        assert a.windows and all(w.fault is not v.fault
                                 for w, v in zip(a.windows, b.windows))


def test_unknown_scenario_raises():
    with pytest.raises(ValueError):
        build_scenario("nope")


def test_scenario_install_schedules_and_traces():
    sc = Scenario("t", [Window(IsolateLeader("both"), at=0.1, until=0.3)])
    raft = RaftParams(election_timeout=0.3, election_jitter=0.1,
                      heartbeat_interval=0.03)
    c = build_cluster(raft, SimParams(seed=2))
    c.wait_for_leader()
    ctx = sc.install(c)
    c.loop.run_until(c.loop.now + 0.5)
    events = [e for _, e in ctx.trace]
    assert events == ["start isolate_leader[both]",
                      "stop isolate_leader[both]"]
    assert not c.net._cut   # healed after the window


def test_partition_faults_cut_and_heal_exactly():
    raft = RaftParams(election_timeout=0.3, election_jitter=0.1,
                      heartbeat_interval=0.03, n_nodes=5)
    c = build_cluster(raft, SimParams(seed=2))
    c.wait_for_leader()
    ctx = FaultContext(c)
    for fault in (IsolateLeader("in"), IsolateLeader("out"),
                  MajorityMinority(True), MajorityMinority(False),
                  PartialPartition()):
        fault.start(ctx)
        assert c.net._cut, fault.name
        fault.stop(ctx)
        assert not c.net._cut, fault.name


def test_leader_nemesis_refires_on_each_new_leader():
    raft = RaftParams(election_timeout=0.3, election_jitter=0.1,
                      heartbeat_interval=0.03)
    c = build_cluster(raft, SimParams(seed=4))
    c.wait_for_leader()
    ctx = FaultContext(c)
    nem = LeaderNemesis(period=0.2, downtime=0.2)
    nem.start(ctx)
    c.loop.run_until(c.loop.now + 4.0)
    nem.stop(ctx)
    strikes = [e for _, e in ctx.trace if e.startswith("nemesis strikes")]
    assert len(strikes) >= 2                  # chased more than one leader
    assert len(set(strikes)) == len(strikes)  # never the same term twice
    c.loop.run_until(c.loop.now + 0.5)
    assert all(n.alive for n in c.nodes.values())


def test_crash_restart_stop_revives_early():
    c = build_cluster(RaftParams(), SimParams(seed=2))
    ldr = c.wait_for_leader()
    ctx = FaultContext(c)
    f = CrashRestart("leader", downtime=60.0)
    f.start(ctx)
    assert not ldr.alive
    f.stop(ctx)   # window closes before the scheduled restart
    assert ldr.alive


# --------------------------------------------------------------- determinism
def _history_fingerprint(seed, scenario_name):
    raft = RaftParams(read_mode=ReadMode.LEASEGUARD, election_timeout=0.3,
                      election_jitter=0.1, heartbeat_interval=0.03,
                      lease_duration=0.6)
    sim = SimParams(seed=seed, sim_duration=0.8, interarrival=4e-3)
    sc = build_scenario(scenario_name)
    res = run_workload(raft, sim, fault_script=sc.install, check=False,
                       settle_time=1.0)
    return [(op.op_type, op.start_ts, op.execution_ts, op.end_ts, op.key,
             str(op.value), op.success) for op in res.history]


@pytest.mark.parametrize("scenario_name",
                         ["leader_nemesis", "dup_reorder", "combo_chaos"])
def test_scenario_runs_are_bit_identical(scenario_name):
    assert _history_fingerprint(5, scenario_name) == \
        _history_fingerprint(5, scenario_name)


def test_random_scenario_deterministic_and_safe():
    a, b = random_scenario(123), random_scenario(123)
    assert [w.fault.name for w in a.windows] == \
        [w.fault.name for w in b.windows]
    assert [(w.at, w.until) for w in a.windows] == \
        [(w.at, w.until) for w in b.windows]
    assert a.expect_safe
    assert random_scenario(124).windows != []

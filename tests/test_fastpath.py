"""Tests for the simulation fast path: lazy-cancel timers, hot-loop
instrumentation, and warm-start cluster snapshots."""

import pytest

from repro.core import RaftParams, SimParams, run_workload
from repro.core.runner import (ClusterSnapshot, build_cluster,
                               clear_warm_cache, warm_cluster)
from repro.core.simulate import (Condition, EventLoop, Future, TimeoutError_,
                                 wait_for)


def _fingerprint(res):
    return [(o.op_type, o.start_ts, o.end_ts, o.key, repr(o.value), o.success)
            for o in res.history]


# ---------------------------------------------------------------- timers


def test_cancelled_timer_never_fires_and_is_reaped():
    loop = EventLoop()
    fired = []
    t = loop.call_later_cancelable(1.0, lambda: fired.append(1))
    loop.call_later(2.0, lambda: fired.append(2))
    t.cancel()
    assert t.cancelled
    loop.run()
    assert fired == [2]
    assert loop.timers_reaped >= 1


def test_cancel_after_fire_is_harmless():
    loop = EventLoop()
    fired = []
    t = loop.call_later_cancelable(0.1, lambda: fired.append(1))
    loop.run()
    t.cancel()
    assert fired == [1]


def test_wait_for_reaps_timeout_entry_on_resolve():
    """The satellite fix: a resolved wait_for must not leave a live
    timeout callback in the heap (it used to fire into a dead future;
    now it is cancelled and reaped)."""
    loop = EventLoop()
    fut = Future(loop)
    results = []

    async def main():
        results.append(await wait_for(fut, 5.0))

    loop.create_task(main())
    loop.call_later(0.1, lambda: fut.set_result("ok"))
    loop.run()
    assert results == ["ok"]
    # the loop drained completely: the 5 s timeout entry was dead, so the
    # clock never had to advance to it... but even if popped, it must be
    # reaped as cancelled, not dispatched
    assert loop.now < 5.0 or loop.timers_reaped >= 1


def test_condition_wait_timeout_entry_cancelled_on_notify():
    loop = EventLoop()
    cond = Condition(loop)
    woke = []

    async def waiter():
        await cond.wait(timeout=9.0)
        woke.append(loop.now)

    loop.create_task(waiter())
    loop.call_later(0.2, cond.notify_all)
    loop.run()
    assert woke == [pytest.approx(0.2)]   # resumed by notify, not timeout
    assert loop.now < 9.0      # never had to idle out to the dead timeout
    assert cond._waiters == []


def test_election_timer_parks_without_heap_stacking():
    """Crash/restart bumps the node's timer generation; the parked timer
    from the old generation must be woken and reaped, not left to stack
    one dead heap entry per restart."""
    raft = RaftParams(election_timeout=0.3, election_jitter=0.1,
                      heartbeat_interval=0.03)
    sim = SimParams(seed=17, sim_duration=0.0)
    c = build_cluster(raft, sim)
    leader = c.wait_for_leader()
    term0 = leader.term
    follower = next(n for n in c.nodes.values() if not n.is_leader())
    for _ in range(8):
        follower.crash()
        c.loop.run_until(c.loop.now + 0.01)
        follower.restart()
        c.loop.run_until(c.loop.now + 0.01)
    c.loop.run_until(c.loop.now + 2.0)
    # the parked timer of each dead generation was woken + reaped; no
    # ghost wakeup from an old generation ever fired an election (the
    # leader's heartbeats reach the restarted follower well inside its
    # election timeout, so any term bump would be a generation leak)
    assert leader.is_leader()
    assert leader.term == term0
    assert follower.alive and follower.term == term0
    assert c.loop.timers_reaped > 0


def test_loop_and_network_counters():
    raft = RaftParams()
    sim = SimParams(seed=1, sim_duration=0.5)
    res = run_workload(raft, sim, check=False)
    assert res.loop_stats["events_popped"] > 0
    assert res.loop_stats["peak_heap"] > 0
    assert res.net_stats["messages_delivered"] > 0
    assert (res.net_stats["messages_delivered"]
            + res.net_stats["messages_dropped"]
            <= res.net_stats["messages_sent"]
            + res.net_stats["messages_delivered"])  # dups can inflate delivery
    assert res.t_end > res.t_start > 0.0


# ----------------------------------------------------------- warm start


@pytest.fixture(autouse=True)
def _fresh_warm_cache():
    clear_warm_cache()
    yield
    clear_warm_cache()


def test_warm_start_same_seed_is_deterministic():
    raft = RaftParams()
    sim = SimParams(seed=5, sim_duration=0.8, interarrival=3e-3)
    r1 = run_workload(raft, sim, warm_start=True)
    r2 = run_workload(raft, sim, warm_start=True)
    assert _fingerprint(r1) == _fingerprint(r2)
    assert len(r1.history) > 0
    assert r1.linearizable_ops > 0


def test_warm_start_survives_cache_rebuild():
    raft = RaftParams()
    sim = SimParams(seed=5, sim_duration=0.8, interarrival=3e-3)
    r1 = run_workload(raft, sim, warm_start=True)
    clear_warm_cache()
    r2 = run_workload(raft, sim, warm_start=True)
    assert _fingerprint(r1) == _fingerprint(r2)


def test_warm_start_seeds_diverge():
    raft = RaftParams()
    r5 = run_workload(raft, SimParams(seed=5, sim_duration=0.8,
                                      interarrival=3e-3), warm_start=True)
    r6 = run_workload(raft, SimParams(seed=6, sim_duration=0.8,
                                      interarrival=3e-3), warm_start=True)
    assert _fingerprint(r5) != _fingerprint(r6)


def test_warm_start_does_not_perturb_cold_runs():
    """Cold runs must replay bit-identically whether or not warm runs
    happened in between (the fast path shares no mutable state with the
    cold path)."""
    raft = RaftParams()
    sim = SimParams(seed=9, sim_duration=0.8, interarrival=3e-3)
    cold1 = run_workload(raft, sim)
    run_workload(raft, sim, warm_start=True)
    cold2 = run_workload(raft, sim)
    assert _fingerprint(cold1) == _fingerprint(cold2)


def test_restored_cluster_has_leader_and_serves():
    raft = RaftParams()
    sim = SimParams(seed=3, sim_duration=0.5)
    c = warm_cluster(raft, sim)
    ldr = c.leader()
    assert ldr is not None and ldr.is_leader()
    # replicated boot state survived the restore on every node
    for n in c.nodes.values():
        assert n.term >= 1
        assert len(n.log) >= 1


def test_snapshot_is_immutable_across_restores():
    raft = RaftParams()
    sim = SimParams(seed=3, sim_duration=0.3, interarrival=3e-3)
    boot = build_cluster(raft, SimParams(seed=99))
    boot.wait_for_leader()
    snap = boot.snapshot()
    r1 = snap.restore(3)
    r1.loop.run_until(r1.loop.now + 1.0)       # mutate the first restore
    r2 = snap.restore(3)
    r3 = snap.restore(3)
    fp = lambda c: [(nid, n.term, len(n.log), n.commit_index)  # noqa: E731
                    for nid, n in sorted(c.nodes.items())]
    assert fp(r2) == fp(r3)


def test_warm_cell_verdict_parity_slice():
    """Tiny warm-vs-cold slice of the fault matrix: same verdict class
    (no violations for a consistent policy under a safe scenario)."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from benchmarks.fault_matrix import run_cell
    for seed in (0, 1):
        cold = run_cell("leaseguard", "leader_crash_restart", seed)
        warm = run_cell("leaseguard", "leader_crash_restart", seed,
                        warm_start=True)
        assert cold["violation"] is None
        assert warm["violation"] is None
        assert warm["ops_ok"] > 0
        assert set(cold["timeline"]) == {"bin_size", "t0", "ok", "fail"}

"""Simulated message-passing network with delays, partitions, and node I/O.

Two latency components model the paper's experiments:

* **network delay**: lognormal one-way latency per message (paper §6.4 uses
  mean 1–10 ms for the latency study; §6.5 uses AWS same-subnet stats,
  mean 191 µs, variance 391 µs²-scaled).
* **I/O service time**: each node serializes outgoing message processing
  through a single queue with a per-message service time. This models the
  disk/NIC contention that makes quorum reads fight with replication for
  I/O — the effect behind the paper's ~10x write-throughput gap (Figs. 9-11)
  and the queueing blow-up in Fig. 10.

RPC layer: ``call()`` returns a Future for the reply, with timeout. One-way
``send()`` is also available. Partitions drop messages in both directions.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from .prob import PRNG
from .simulate import EventLoop, Future, TimeoutError_, wait_for


@dataclass
class NetParams:
    one_way_latency_mean: float = 191e-6
    one_way_latency_variance: float = 391e-6 ** 2
    io_service_time: float = 0.0       # per outgoing message, serialized per node
    rpc_timeout: float = 0.5


class Network:
    def __init__(self, loop: EventLoop, prng: PRNG, params: NetParams) -> None:
        self.loop = loop
        self.prng = prng
        self.params = params
        self._handlers: dict[int, Callable[[int, Any], Any]] = {}
        self._partitioned: set[frozenset[int]] = set()
        self._down: set[int] = set()
        self._io_busy_until: dict[int, float] = {}
        self._rpc_seq = 0
        self._pending: dict[int, Future] = {}
        self.messages_sent = 0
        self.bytes_sent = 0

    # -- topology ----------------------------------------------------------
    def register(self, node_id: int, handler: Callable[[int, Any], Any]) -> None:
        """handler(src, msg) -> reply or None; called on delivery."""
        self._handlers[node_id] = handler

    def partition(self, a: int, b: int) -> None:
        self._partitioned.add(frozenset((a, b)))

    def heal(self, a: int = -1, b: int = -1) -> None:
        if a < 0:
            self._partitioned.clear()
        else:
            self._partitioned.discard(frozenset((a, b)))

    def set_down(self, node_id: int, down: bool = True) -> None:
        if down:
            self._down.add(node_id)
        else:
            self._down.discard(node_id)

    def reachable(self, src: int, dst: int) -> bool:
        return (
            src not in self._down
            and dst not in self._down
            and frozenset((src, dst)) not in self._partitioned
        )

    # -- I/O serialization ---------------------------------------------------
    def _io_delay(self, node_id: int) -> float:
        """Serialize a node's message processing through one I/O queue."""
        svc = self.params.io_service_time
        if svc <= 0:
            return 0.0
        start = max(self.loop.now, self._io_busy_until.get(node_id, 0.0))
        self._io_busy_until[node_id] = start + svc
        return (start + svc) - self.loop.now

    # -- messaging -----------------------------------------------------------
    def send(self, src: int, dst: int, msg: Any, size: int = 256) -> None:
        """Fire-and-forget delivery (reply, if any, is discarded)."""
        self._transmit(src, dst, msg, size, reply_to=None)

    def call(self, src: int, dst: int, msg: Any, size: int = 256,
             timeout: Optional[float] = None) -> "Future":
        """RPC: deliver msg; handler's return value resolves the future."""
        self._rpc_seq += 1
        rid = self._rpc_seq
        fut = Future(self.loop)
        self._pending[rid] = fut
        self._transmit(src, dst, msg, size, reply_to=rid)
        return fut

    async def call_wait(self, src: int, dst: int, msg: Any, size: int = 256,
                        timeout: Optional[float] = None) -> Any:
        t = timeout if timeout is not None else self.params.rpc_timeout
        return await wait_for(self.call(src, dst, msg, size), t)

    def _transmit(self, src: int, dst: int, msg: Any, size: int,
                  reply_to: Optional[int]) -> None:
        self.messages_sent += 1
        self.bytes_sent += size
        io = self._io_delay(src)
        delay = io + self.prng.lognormal_mean_var(
            self.params.one_way_latency_mean, self.params.one_way_latency_variance
        )

        def deliver() -> None:
            if not self.reachable(src, dst):
                return  # dropped; RPC future times out at caller
            handler = self._handlers.get(dst)
            if handler is None:
                return
            reply = handler(src, msg)
            if reply_to is not None and reply is not None:
                # reply travels back with its own I/O + network delay
                rio = self._io_delay(dst)
                rdelay = rio + self.prng.lognormal_mean_var(
                    self.params.one_way_latency_mean,
                    self.params.one_way_latency_variance,
                )

                def deliver_reply() -> None:
                    if not self.reachable(dst, src):
                        return
                    fut = self._pending.pop(reply_to, None)
                    if fut is not None and not fut.done():
                        fut.set_result(reply)

                self.loop.call_later(rdelay, deliver_reply)

        self.loop.call_later(delay, deliver)

"""Nemesis substrate: the Fault protocol, fault windows, and the Scenario
scheduler.

A :class:`Fault` is a reversible perturbation of the simulated cluster
(cut links, skew clocks, crash nodes, perturb messages). A
:class:`Scenario` is a declarative schedule of faults — each
:class:`Window` starts its fault at a relative time and (optionally)
stops it later — installed on the deterministic event loop, so a
(seed, scenario, policy) triple always replays the identical run.

``Scenario.install`` is compatible with ``run_workload(fault_script=...)``:
it is called once with the built cluster, just before the workload
starts, and schedules everything it needs.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Optional

if TYPE_CHECKING:
    from ..core.runner import Cluster


class FaultContext:
    """What a fault may touch: the cluster plus deterministic helpers for
    picking victims. One context per installed scenario; it also keeps a
    trace of fault activations for tests and debugging."""

    def __init__(self, cluster: "Cluster") -> None:
        self.cluster = cluster
        self.trace: list[tuple[float, str]] = []

    # -- shorthands --------------------------------------------------------
    @property
    def loop(self):
        return self.cluster.loop

    @property
    def net(self):
        return self.cluster.net

    @property
    def nodes(self):
        return self.cluster.nodes

    def note(self, event: str) -> None:
        self.trace.append((self.loop.now, event))
        tr = self.loop.tracer
        if tr is not None:
            # the scheduler's "start <name>" / "stop <name>" notes become
            # structured fault windows in the trace
            if event.startswith("start "):
                tr.emit("fault", op="start", label=event[6:])
            elif event.startswith("stop "):
                tr.emit("fault", op="stop", label=event[5:])
            else:
                tr.emit("fault", op="note", label=event)

    # -- victim selection (deterministic given cluster state) --------------
    def ids(self) -> list[int]:
        return sorted(self.nodes)

    def leader(self):
        return self.cluster.leader()

    def leader_id(self) -> int:
        """The directory's current leader, or the lowest node id if no
        leader is known yet."""
        ldr = self.leader()
        return ldr.id if ldr is not None else self.ids()[0]

    def followers(self) -> list[int]:
        lid = self.leader_id()
        return [i for i in self.ids() if i != lid]

    def minority(self, with_leader: bool = False) -> list[int]:
        """A deterministic strict minority (⌊n/2⌋ nodes): the leader plus
        the lowest-id followers, or followers only."""
        k = len(self.ids()) // 2
        if with_leader:
            return ([self.leader_id()] + self.followers())[:k]
        return self.followers()[:k]

    def pick(self, scope: str) -> list[int]:
        """Resolve a victim scope name to node ids: ``leader``,
        ``followers``, ``minority`` (followers only), ``minority+leader``,
        or ``all``."""
        if scope == "leader":
            return [self.leader_id()]
        if scope == "followers":
            return self.followers()
        if scope == "minority":
            return self.minority()
        if scope == "minority+leader":
            return self.minority(with_leader=True)
        if scope == "all":
            return self.ids()
        raise ValueError(f"unknown victim scope {scope!r}")


class Fault:
    """Base class: a reversible perturbation. ``start`` applies it,
    ``stop`` undoes it; both run on the event loop at scheduled times.
    Instances are single-use (they carry undo state), so scenario
    factories build fresh ones per run."""

    name = "fault"

    def start(self, ctx: FaultContext) -> None:
        raise NotImplementedError

    def stop(self, ctx: FaultContext) -> None:
        pass


@dataclass
class Window:
    """Activate ``fault`` at ``at`` seconds after scenario install; stop it
    at ``until`` (None = leave active to the end of the run)."""

    fault: Fault
    at: float
    until: Optional[float] = None


class Scenario:
    """A named, declarative fault schedule over one run."""

    def __init__(self, name: str, windows: list[Window],
                 expect_safe: bool = True, description: str = "",
                 raft_overrides: Optional[dict] = None,
                 meta: Optional[dict] = None) -> None:
        self.name = name
        self.windows = windows
        #: True = inside the fault model every *consistent* policy claims
        #: to tolerate; the matrix asserts zero violations. False = exceeds
        #: the model (lying clocks, disk loss): violations are expected
        #: findings, not failures.
        self.expect_safe = expect_safe
        self.description = description
        #: RaftParams kwargs the scenario *requires* for its expect_safe
        #: classification to hold (e.g. corruption scenarios need
        #: ``entry_checksums=True``). Harnesses merge these on top of their
        #: per-policy config; scenarios with no overrides leave historical
        #: runs untouched.
        self.raft_overrides = dict(raft_overrides or {})
        #: free-form scenario annotations (e.g. flap duty cycle) for tests.
        self.meta = dict(meta or {})
        self.ctx: Optional[FaultContext] = None

    def install(self, cluster: "Cluster") -> FaultContext:
        """Schedule every window on the cluster's event loop (relative to
        now, i.e. to workload start). Compatible with
        ``run_workload(fault_script=scenario.install)``."""
        ctx = FaultContext(cluster)
        self.ctx = ctx
        self._schedule(ctx)
        return ctx

    def _schedule(self, ctx: FaultContext) -> None:
        """Schedule the windows against an already-built context.
        Subclasses (the fleet's :class:`~repro.fleet.faults.FleetScenario`)
        install a richer context and reuse this scheduler unchanged."""
        cluster = ctx.cluster
        for w in self.windows:
            def fire(w=w) -> None:
                ctx.note(f"start {w.fault.name}")
                w.fault.start(ctx)

            cluster.loop.call_later(w.at, fire)
            if w.until is not None:
                def cease(w=w) -> None:
                    ctx.note(f"stop {w.fault.name}")
                    w.fault.stop(ctx)

                cluster.loop.call_later(w.until, cease)

    def __repr__(self) -> str:
        return (f"Scenario({self.name!r}, {len(self.windows)} windows, "
                f"expect_safe={self.expect_safe})")

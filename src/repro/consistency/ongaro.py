"""Ongaro leases ([41] §6.4.1, as implemented in paper §7.1).

The leader holds a lease iff a majority of its last-successful
AppendEntries *start* times are less than ET old; while leased, reads
are served locally. Safety additionally requires followers to refuse to
vote within ET of hearing from a leader — which is why this mechanism
delays elections, the availability cost LeaseGuard avoids (paper §3
"Elections").
"""

from __future__ import annotations

from ..core.raft import ReadResult, RequestVote
from .base import ConsistencyPolicy


class OngaroLeasePolicy(ConsistencyPolicy):
    name = "ongaro_lease"

    def __init__(self, node) -> None:
        super().__init__(node)
        # peer -> start time of the last successful AppendEntries to it
        self.acked_at: dict[int, float] = {}

    def on_become_leader(self) -> None:
        self.acked_at = {}

    def on_append_response(self, peer: int, sent_at: float) -> None:
        self.acked_at[peer] = sent_at

    def gate_vote(self, msg: RequestVote) -> bool:
        # do not vote within ET of hearing from a leader ([41] §6.4.1);
        # LeaseGuard deliberately does NOT delay elections (paper §3).
        n = self.node
        return n.loop.now - n._last_heartbeat < n.p.election_timeout

    def has_lease(self) -> bool:
        n = self.node
        fresh = 1  # self counts as "now"
        for p in n.peers:
            s = self.acked_at.get(p)
            if s is not None and n.loop.now - s < n.p.election_timeout:
                fresh += 1
        return fresh >= n.majority()

    async def gate_read(self, key: str) -> ReadResult:
        n = self.node
        if not n.is_leader():
            return ReadResult(False, error="not_leader")
        if not self.has_lease():
            return ReadResult(False, error="no_lease")
        return await self._local_read(key, n.term)

"""Quickstart: LeaseGuard in 60 seconds.

Builds a 3-node replica set, shows zero-roundtrip linearizable reads,
then crashes the leader and shows the two availability optimizations:
deferred-commit writes and inherited-lease reads (paper §3.2/§3.3).

Any policy from the consistency registry can be swapped in — the same
script then shows what that mechanism does around a failover:

Run:  PYTHONPATH=src python examples/quickstart.py [--policy leaseguard]
      PYTHONPATH=src python examples/quickstart.py --policy readindex
"""

import argparse

from repro.consistency import benchmark_configs, resolve_read_mode
from repro.core import RaftParams, SimParams, build_cluster

DELTA = 2.0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="leaseguard",
                    choices=sorted(benchmark_configs(variants=False)),
                    help="consistency policy to demo")
    args = ap.parse_args()
    mode = resolve_read_mode(args.policy)
    leasey = args.policy in ("leaseguard", "follower_read")

    cluster = build_cluster(
        RaftParams(read_mode=mode, lease_duration=DELTA,
                   election_timeout=0.5),
        SimParams(seed=42))
    loop = cluster.loop
    run = lambda coro: loop.run_until_complete(loop.create_task(coro))

    leader = cluster.wait_for_leader()
    print(f"t={loop.now:.2f}s  leader is node {leader.id} "
          f"(policy: {args.policy})")

    # --- normal operation: writes replicate; read cost depends on policy --
    run(leader.client_write("user:42", "alice"))
    msgs_before = cluster.net.messages_sent
    res = run(leader.client_read("user:42"))
    print(f"t={loop.now:.2f}s  read -> {res.value}  "
          f"(network messages used: {cluster.net.messages_sent - msgs_before})")

    if mode.value == "follower_read":
        follower = next(n for n in cluster.nodes.values() if n is not leader)
        loop.run_until(loop.now + 0.2)
        msgs_before = cluster.net.messages_sent
        res = run(follower.client_read("user:42"))
        print(f"t={loop.now:.2f}s  follower read on node {follower.id} -> "
              f"{res.value} (messages: "
              f"{cluster.net.messages_sent - msgs_before}, one RPC to the "
              f"leader for a read index)")

    # --- leader crash ----------------------------------------------------
    t_crash = loop.now
    leader.crash()
    print(f"t={loop.now:.2f}s  leader {leader.id} crashed")
    new = None
    while new is None:
        loop.run_until(loop.now + 0.05)
        new = next((n for n in cluster.nodes.values()
                    if n.is_leader() and n is not leader), None)
    print(f"t={loop.now:.2f}s  node {new.id} elected"
          + (f" (old lease valid until ~t={t_crash + DELTA:.2f}s)"
             if leasey else ""))

    if not leasey:
        # no inherited lease to navigate: the new leader serves immediately
        res = run(new.client_read("user:42"))
        print(f"t={loop.now:.2f}s  post-election read -> ok={res.ok} "
              f"value={res.value}")
        res = run(new.client_write("user:42", "bob"))
        print(f"t={loop.now:.2f}s  post-election write acked ok={res.ok}")
        res = run(new.client_read("user:42"))
        print(f"t={loop.now:.2f}s  read -> {res.value}")
        return

    # --- inherited lease read: consistent, instant, zero roundtrips -----
    res = run(new.client_read("user:42"))
    if res.ok:
        print(f"t={loop.now:.2f}s  inherited-lease read -> {res.value} "
              f"(gate blocked: {new._commit_gate_blocked()})")
    else:
        # the old leader crashed before broadcasting its last commitIndex:
        # this key sits in the LIMBO REGION (paper §3.3) and is correctly
        # rejected; unaffected keys still read fine
        print(f"t={loop.now:.2f}s  inherited-lease read rejected "
              f"({res.error}: key written in the limbo region — "
              f"serving it could violate linearizability)")
        other = run(new.client_read("other_key"))
        print(f"t={loop.now:.2f}s  read of unaffected key -> ok={other.ok} "
              f"value={other.value}")

    # --- deferred-commit write: accepted now, acked at lease expiry -----
    t0 = loop.now
    res = run(new.client_write("user:42", "bob"))
    print(f"t={loop.now:.2f}s  deferred write acked ok={res.ok} "
          f"(waited {loop.now - t0:.2f}s for the old lease to expire)")
    res = run(new.client_read("user:42"))
    print(f"t={loop.now:.2f}s  read -> {res.value}")


if __name__ == "__main__":
    main()

"""Pluggable consistency layer: one module per mechanism (paper §6-§7).

``ReadMode`` (repro.core.params) stays the user-facing switch; this
package owns the mapping from mode to policy implementation. The
replication core (repro.core.raft) delegates every consistency decision
— commit gating, read serving, vote delays, lease upkeep, extra RPCs —
to the node's policy object.

Adding a mechanism is a one-file drop-in:

1. subclass :class:`ConsistencyPolicy` in a new module here,
2. add a ``ReadMode`` value whose string equals the policy's ``name``,
3. add one ``REGISTRY`` entry below.

Benchmarks, the coordinator, and the conformance tests iterate the
registry, so the new mechanism shows up everywhere automatically.
"""

from __future__ import annotations

from ..core.params import ReadMode
from .base import ConsistencyPolicy
from .follower import FollowerReadPolicy, ReadIndexReply, ReadIndexRequest
from .inconsistent import InconsistentPolicy
from .leaseguard import LeaseGuardPolicy
from .ongaro import OngaroLeasePolicy
from .quorum import QuorumPolicy
from .readindex import ReadIndexPolicy

#: mode -> policy class; iteration order is the canonical benchmark order.
REGISTRY: dict[ReadMode, type[ConsistencyPolicy]] = {
    ReadMode.INCONSISTENT: InconsistentPolicy,
    ReadMode.QUORUM: QuorumPolicy,
    ReadMode.ONGARO_LEASE: OngaroLeasePolicy,
    ReadMode.LEASEGUARD: LeaseGuardPolicy,
    ReadMode.READ_INDEX: ReadIndexPolicy,
    ReadMode.FOLLOWER_READ: FollowerReadPolicy,
}


def make_policy(node) -> ConsistencyPolicy:
    """Instantiate the policy selected by ``node.p.read_mode``."""
    try:
        cls = REGISTRY[node.p.read_mode]
    except KeyError:
        raise ValueError(
            f"no consistency policy registered for {node.p.read_mode!r}"
        ) from None
    return cls(node)


def resolve_read_mode(mode) -> ReadMode:
    """Accept a ReadMode, a policy-name string, or a policy class."""
    if isinstance(mode, ReadMode):
        return mode
    if isinstance(mode, type) and issubclass(mode, ConsistencyPolicy):
        for m, cls in REGISTRY.items():
            if cls is mode:
                return m
        raise ValueError(f"policy class {mode.__name__} is not registered")
    if isinstance(mode, str):
        return ReadMode(mode)
    raise ValueError(f"unknown consistency mode {mode!r}")


def benchmark_configs(variants: bool = True) -> dict[str, dict]:
    """name -> benchmark config, one entry per benchmark row.

    A config is RaftParams kwargs, except for the optional ``sim_params``
    key: SimParams overrides a policy needs to be exercised meaningfully
    (e.g. follower_read routes a slice of reads to followers). Consumers
    split the two with :func:`split_bench_config`.

    ``variants=True`` includes per-policy flag variants (the paper's
    log_lease / defer_commit ablation ladder); ``variants=False`` yields
    exactly one config per registered policy.
    """
    out: dict[str, dict] = {}
    for mode, cls in REGISTRY.items():
        vs = cls.bench_variants()
        if not variants:
            # keep only the policy's canonical config (named after it)
            vs = {cls.name: vs.get(cls.name, {})}
        for name, flags in vs.items():
            out[name] = dict(read_mode=mode, **flags)
    return out


def split_bench_config(config: dict) -> tuple[dict, dict]:
    """Split a :func:`benchmark_configs` entry into
    (RaftParams kwargs, SimParams kwargs)."""
    raft = dict(config)
    sim = raft.pop("sim_params", {})
    return raft, sim


__all__ = [
    "ConsistencyPolicy", "FollowerReadPolicy", "InconsistentPolicy",
    "LeaseGuardPolicy", "OngaroLeasePolicy", "QuorumPolicy",
    "ReadIndexPolicy", "ReadIndexReply", "ReadIndexRequest", "REGISTRY",
    "ReadMode", "benchmark_configs", "make_policy", "resolve_read_mode",
    "split_bench_config",
]

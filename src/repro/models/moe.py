"""Mixture-of-Experts layer: top-k routing, capacity-based dispatch
(GShard-style scatter/gather — static shapes, shards cleanly with experts
on the 'model'/'expert' mesh axis), optional parallel dense residual
(arctic)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.ctx import constrain
from .layers import dense_init


def init_moe(key: jax.Array, cfg: ArchConfig, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    keys = jax.random.split(key, 5)
    p = {
        "router": dense_init(keys[0], (d, e), jnp.float32),  # fp32 routing
        "w_gate": dense_init(keys[1], (e, d, f), dtype),
        "w_up": dense_init(keys[2], (e, d, f), dtype),
        "w_down": dense_init(keys[3], (e, f, d), dtype),
    }
    if cfg.moe_dense_residual:
        ks = jax.random.split(keys[4], 3)
        p["dense"] = {
            "w_gate": dense_init(ks[0], (d, f), dtype),
            "w_up": dense_init(ks[1], (d, f), dtype),
            "w_down": dense_init(ks[2], (f, d), dtype),
        }
    return p


def apply_moe(p: dict, x: jax.Array, cfg: ArchConfig) -> tuple[jax.Array, jax.Array]:
    """x: (T, d) tokens (caller flattens batch×seq). Returns (out, aux_loss).

    Dispatch layouts (``repro.sharding.ctx.moe_groups()`` selects):
    * flat (1 group): one global capacity pool — simple, but with tokens
      sharded over `data` the scatter-add produces PARTIAL buffers that
      GSPMD all-reduces (§Perf iteration 6 baseline);
    * group-local (n_groups = dp extent): each data shard owns a private
      capacity slice of every expert — the scatter/gather become local
      writes + one all-gather of the bf16 buffer over `data`, removing
      both dispatch all-reduces. Classic GShard "group" dispatch, aligned
      so the group dim shards exactly like the batch.
    """
    from ..sharding.ctx import moe_groups
    t, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    f = cfg.d_ff
    groups = moe_groups()
    if groups > 1 and t % groups == 0:
        return _apply_moe_grouped(p, x, cfg, groups)

    logits = jnp.dot(x.astype(jnp.float32), p["router"])       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)             # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], e), axis=0)
    aux = e * jnp.sum(me * ce)

    # capacity-based dispatch
    capacity = max(1, int(cfg.capacity_factor * t * k / e))
    flat_e = expert_idx.reshape(-1)                             # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)         # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) * onehot                   # rank+1
    pos = jnp.sum(pos, axis=-1) - 1                             # (T*k,)
    valid = pos < capacity
    pos_c = jnp.clip(pos, 0, capacity - 1)

    x_rep = jnp.repeat(x, k, axis=0)                            # (T*k, d)
    x_rep = x_rep * valid[:, None].astype(x.dtype)
    buf = jnp.zeros((e, capacity, d), x.dtype)
    buf = buf.at[flat_e, pos_c].add(x_rep)                      # scatter
    # expert dim on the model axis (EP); the scatter above becomes the
    # all-to-all token dispatch. (Tiling capacity over data as well was
    # tried and REFUTED: GSPMD resolves the token->tile scatter by full
    # replication, 6x worse — see EXPERIMENTS.md §Perf.)
    buf = constrain(buf, "tp", None, None)

    # expert FFN, batched over experts: shards with E on the model axis
    g = constrain(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]),
                  "tp", None, None)
    u = constrain(jnp.einsum("ecd,edf->ecf", buf, p["w_up"]),
                  "tp", None, None)
    h = jax.nn.silu(g) * u
    out_buf = constrain(jnp.einsum("ecf,efd->ecd", h, p["w_down"]),
                        "tp", None, None)                       # (E, C, d)

    # combine: gather each token's expert outputs, weight by gates
    gathered = out_buf[flat_e, pos_c]                           # (T*k, d)
    gathered = gathered * (gate_vals.reshape(-1, 1).astype(x.dtype)
                           * valid[:, None].astype(x.dtype))
    out = jnp.sum(gathered.reshape(t, k, d), axis=1)

    if cfg.moe_dense_residual:
        out = out + _dense_residual(p, x)
    return out, aux


def _dense_residual(p: dict, x: jax.Array) -> jax.Array:
    dp = p["dense"]
    g = constrain(jnp.dot(x, dp["w_gate"]), "dp", "tp")
    u = constrain(jnp.dot(x, dp["w_up"]), "dp", "tp")
    return constrain(jnp.dot(jax.nn.silu(g) * u, dp["w_down"]), "dp", None)


def _apply_moe_grouped(p: dict, x: jax.Array, cfg: ArchConfig,
                       groups: int) -> tuple[jax.Array, jax.Array]:
    """Group-local dispatch (§Perf iteration 6): the token axis is split
    into ``groups`` contiguous slices aligned with the `data` sharding;
    each group has a private per-expert capacity slice, so the dispatch
    scatter and combine gather touch only group-local rows."""
    t, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    tg = t // groups

    logits = jnp.dot(x.astype(jnp.float32), p["router"])       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)             # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], e), axis=0)
    aux = e * jnp.sum(me * ce)

    cap_g = max(1, int(cfg.capacity_factor * tg * k / e))
    flat_e = expert_idx.reshape(groups, tg * k)                 # (G, Tg*k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)         # (G, Tg*k, E)
    pos = jnp.cumsum(onehot, axis=1) * onehot                   # rank+1
    pos = jnp.sum(pos, axis=-1) - 1                             # (G, Tg*k)
    valid = pos < cap_g
    pos_c = jnp.clip(pos, 0, cap_g - 1)

    x_rep = jnp.repeat(x.reshape(groups, tg, d), k, axis=1)     # (G, Tg*k, d)
    x_rep = constrain(x_rep * valid[..., None].astype(x.dtype),
                      "dp", None, None)
    # group-local scatter: each group writes only its own capacity slice
    buf = jnp.zeros((groups, e, cap_g, d), x.dtype)
    gidx = jnp.arange(groups)[:, None].repeat(tg * k, 1)        # (G, Tg*k)
    buf = buf.at[gidx, flat_e, pos_c].add(x_rep)
    # experts on tp, groups stay on dp END-TO-END (4-D einsums: merging
    # (G@dp, Cg) into one dim would force GSPMD to replicate); expert
    # weights are FSDP-sharded on their NON-contraction dim (rules.py) so
    # the matmuls gather weights over data instead of all-reducing
    # (E, G, Cg, f) partials
    buf = constrain(buf.transpose(1, 0, 2, 3), "tp", "dp", None, None)

    g_ = constrain(jnp.einsum("egcd,edf->egcf", buf, p["w_gate"]),
                   "tp", "dp", None, None)
    u_ = constrain(jnp.einsum("egcd,edf->egcf", buf, p["w_up"]),
                   "tp", "dp", None, None)
    h = jax.nn.silu(g_) * u_
    out_buf = constrain(jnp.einsum("egcf,efd->egcd", h, p["w_down"]),
                        "tp", "dp", None, None)
    out_buf = out_buf.transpose(1, 0, 2, 3)

    gathered = out_buf[gidx, flat_e, pos_c]                     # (G, Tg*k, d)
    gathered = gathered * (gate_vals.reshape(groups, tg * k, 1)
                           .astype(x.dtype) * valid[..., None].astype(x.dtype))
    out = jnp.sum(gathered.reshape(groups, tg, k, d), axis=2)
    out = constrain(out.reshape(t, d), "dp", None)

    if cfg.moe_dense_residual:
        out = out + _dense_residual(p, x)
    return out, aux

"""Vanilla-Raft behaviour: elections, replication, completeness."""

import pytest

from repro.core import (RaftParams, ReadMode, SimParams, build_cluster)


def make(raft=None, sim=None, **kw):
    raft = raft or RaftParams(**kw)
    sim = sim or SimParams()
    return build_cluster(raft, sim)


def settle(cluster, dt):
    cluster.loop.run_until(cluster.loop.now + dt)


def write(cluster, node, key, value):
    return cluster.loop.run_until_complete(
        cluster.loop.create_task(node.client_write(key, value)))


def read(cluster, node, key):
    return cluster.loop.run_until_complete(
        cluster.loop.create_task(node.client_read(key)))


def test_single_leader_elected():
    c = make()
    ldr = c.wait_for_leader()
    settle(c, 1.0)
    leaders = [n for n in c.nodes.values() if n.is_leader()]
    assert leaders == [ldr]
    assert all(n.term == ldr.term for n in c.nodes.values())


def test_write_replicates_to_all():
    c = make()
    ldr = c.wait_for_leader()
    res = write(c, ldr, "x", 1)
    assert res.ok
    settle(c, 0.5)
    for n in c.nodes.values():
        assert n.data.get("x") == [1]
        assert n.commit_index >= 1


def test_write_to_follower_rejected():
    c = make()
    ldr = c.wait_for_leader()
    follower = next(n for n in c.nodes.values() if n is not ldr)
    res = write(c, follower, "x", 1)
    assert not res.ok and res.error == "not_leader"


def test_leader_crash_new_leader_has_committed_entries():
    """Leader Completeness: committed entries survive failover."""
    c = make()
    ldr = c.wait_for_leader()
    for i in range(5):
        assert write(c, ldr, f"k{i}", i).ok
    ldr.crash()
    settle(c, 2.0)
    new = next(n for n in c.nodes.values() if n.is_leader())
    assert new is not ldr
    for i in range(5):
        assert f"k{i}" in {e.key for e in new.log}
    settle(c, 1.5)  # allow gate to open and state machine to catch up
    for i in range(5):
        assert new.data.get(f"k{i}") == [i]


def test_crashed_node_restarts_and_catches_up():
    c = make()
    ldr = c.wait_for_leader()
    follower = next(n for n in c.nodes.values() if n is not ldr)
    follower.crash()
    for i in range(5):
        assert write(c, ldr, "k", i).ok
    follower.restart()
    settle(c, 2.0)
    assert follower.data.get("k") == [0, 1, 2, 3, 4]


def test_deposed_leader_steps_down_on_higher_term():
    c = make()
    ldr = c.wait_for_leader()
    others = [n for n in c.nodes.values() if n is not ldr]
    # isolate the leader; a new one is elected; heal; old must step down
    for o in others:
        c.net.partition(ldr.id, o.id)
    settle(c, 2.0)
    new = next(n for n in others if n.is_leader())
    assert new.term > ldr.term
    c.net.heal()
    settle(c, 1.0)
    assert ldr.state == "follower"
    assert ldr.term == new.term


def test_log_matching_after_partition_heal():
    c = make()
    ldr = c.wait_for_leader()
    others = [n for n in c.nodes.values() if n is not ldr]
    for o in others:
        c.net.partition(ldr.id, o.id)
    # divergent suffix on the isolated leader (never commits)
    c.loop.create_task(ldr.client_write("lost", 99))
    settle(c, 2.5)
    new = next(n for n in others if n.is_leader())
    assert write(c, new, "kept", 1).ok
    c.net.heal()
    settle(c, 2.0)
    # all logs identical, lost write gone everywhere
    logs = [[(e.term, e.key, e.value) for e in n.log] for n in c.nodes.values()]
    assert logs[0] == logs[1] == logs[2]
    assert all("lost" not in n.data for n in c.nodes.values())
    assert all(n.data.get("kept") == [1] for n in c.nodes.values())


def test_five_node_cluster_survives_two_crashes():
    c = make(n_nodes=5)
    ldr = c.wait_for_leader()
    assert write(c, ldr, "a", 1).ok
    followers = [n for n in c.nodes.values() if n is not ldr]
    followers[0].crash()
    followers[1].crash()
    settle(c, 1.0)
    assert write(c, ldr, "a", 2).ok
    settle(c, 1.0)
    live = [n for n in c.nodes.values() if n.alive]
    assert len(live) == 3
    for n in live:
        assert n.data.get("a") == [1, 2]

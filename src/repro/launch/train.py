"""End-to-end training driver with the LeaseGuard control plane.

Every run:
  * registers with the cluster registry (membership),
  * restores from the latest **committed** checkpoint manifest (leased
    zero-roundtrip read) if one exists,
  * trains with the jitted microbatched train_step,
  * reports per-step times (straggler table),
  * commits a checkpoint manifest through the Raft log every
    ``--ckpt-every`` steps,
  * optionally injects a coordinator-leader crash mid-run (--failover-at)
    to demonstrate that training does not block on coordinator failover
    (deferred-commit writes + inherited-lease reads).

Presets: ``tiny`` (CPU-friendly demo), ``100m`` (~100M-param model —
the deliverable driver; a few hundred steps on real hardware).

Usage:
  PYTHONPATH=src python -m repro.launch.train --preset tiny --steps 30
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp

from ..configs import get_arch
from ..configs.base import ArchConfig, ShapeConfig
from ..coord.kvstore import LocalCoordinator
from ..coord.registry import ClusterRegistry
from ..train.checkpoint import restore_checkpoint, save_checkpoint
from ..train.data import DataIterator
from ..train.optimizer import OptConfig
from ..train.train_step import init_train_state, train_step

PRESETS = {
    "tiny": ArchConfig(
        name="tiny-12m", family="dense", n_layers=4, d_model=256,
        n_heads=4, n_kv_heads=2, d_ff=1024, vocab_size=4096,
        grad_accum=1, param_dtype="float32"),
    "100m": ArchConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=2304, vocab_size=32000,
        grad_accum=1, param_dtype="float32"),
}


def run_training(cfg: ArchConfig, shape: ShapeConfig, steps: int,
                 ckpt_dir: str, ckpt_every: int = 20,
                 registry: ClusterRegistry | None = None,
                 worker_id: str = "worker-0",
                 failover_at: int | None = None,
                 log_every: int = 5) -> dict:
    registry = registry or ClusterRegistry()
    registry.register_worker(worker_id, {"arch": cfg.name})

    # warmup proportional to short runs: a 40-step demo should not spend
    # half its budget below full LR
    opt_cfg = OptConfig(name=cfg.optimizer,
                        warmup_steps=min(20, max(2, steps // 10)),
                        total_steps=max(steps, 100))
    latest = registry.latest_checkpoint()
    template = jax.eval_shape(
        partial(init_train_state, jax.random.PRNGKey(0), cfg, opt_cfg))
    if latest is not None and latest["extra"].get("arch") == cfg.name:
        state = restore_checkpoint(template, latest)
        start_step = int(latest["step"])
        print(f"[train] resumed from committed step {start_step} "
              f"(leased read, zero roundtrips)")
    else:
        state = init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
        start_step = 0

    data = DataIterator(cfg, shape, start_step=start_step)
    step_fn = jax.jit(partial(train_step, cfg=cfg, opt_cfg=opt_cfg),
                      donate_argnums=(0,))

    losses = []
    for step in range(start_step, steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        losses.append(loss)
        registry.report_step_time(worker_id, step, dt)
        registry.heartbeat(worker_id)   # feeds live_workers(ttl=...)
        if failover_at is not None and step == failover_at:
            crashed = registry.coord.crash_leader()
            print(f"[train] coordinator leader {crashed} crashed at step "
                  f"{step}; training continues through failover")
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"({dt:.2f}s)", flush=True)
        if (step + 1) % ckpt_every == 0 or step == steps - 1:
            manifest = save_checkpoint(
                ckpt_dir, step + 1, state,
                extra={"arch": cfg.name, "data": data.state()},
                registry=registry)
            print(f"[train] checkpoint step {step+1} committed via Raft "
                  f"(sha {manifest['sha256'][:10]})")
    stats = registry.coord.stats()
    print(f"[train] coordinator stats: {stats}")
    flags = registry.straggler_flags()
    if any(flags.values()):
        print(f"[train] stragglers flagged: {flags}")
    return {"losses": losses, "state": state, "registry": registry}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default=None)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config of --arch")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--failover-at", type=int, default=None)
    args = ap.parse_args()

    if args.preset:
        cfg = PRESETS[args.preset]
    elif args.arch:
        cfg = get_arch(args.arch)
        if args.smoke:
            cfg = cfg.reduced()
    else:
        cfg = PRESETS["tiny"]
    shape = ShapeConfig("cli", "train", args.seq, args.batch)
    run_training(cfg, shape, args.steps, args.ckpt_dir,
                 ckpt_every=args.ckpt_every, failover_at=args.failover_at)


if __name__ == "__main__":
    main()

"""End-to-end training with the LeaseGuard control plane.

Trains a small LM (default: the 'tiny' preset; pass --preset 100m for the
~100M-parameter deliverable driver) for a few hundred steps with:
  * Raft-committed checkpoint manifests,
  * a coordinator-leader crash injected mid-run (training never blocks),
  * checkpoint/restart: the script kills training after N steps, builds a
    FRESH process state, restores from the latest committed manifest, and
    verifies the loss curve continues deterministically.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 60] [--preset 100m]
"""

import argparse
import shutil
import tempfile

from repro.configs.base import ShapeConfig
from repro.coord.registry import ClusterRegistry
from repro.launch.train import PRESETS, run_training


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    shape = ShapeConfig("example", "train", args.seq, args.batch)
    ckpt_dir = tempfile.mkdtemp(prefix="repro_train_")
    try:
        registry = ClusterRegistry()
        half = args.steps // 2
        print(f"=== phase 1: train to step {half}, crash coordinator "
              f"leader at {half // 2}, checkpoint every 10 ===")
        out1 = run_training(cfg, shape, half, ckpt_dir, ckpt_every=10,
                            registry=registry, failover_at=half // 2)

        print(f"\n=== phase 2: 'process restart' — fresh state restored "
              f"from the committed manifest, train to {args.steps} ===")
        out2 = run_training(cfg, shape, args.steps, ckpt_dir,
                            ckpt_every=10, registry=registry,
                            worker_id="worker-0-restarted")
        print(f"\nfinal loss: {out2['losses'][-1]:.4f} "
              f"(phase-1 end: {out1['losses'][-1]:.4f})")
        print("checkpoint history (all Raft-committed):")
        for m in registry.checkpoint_history():
            print(f"  step {m['step']:5d}  sha {m['sha256'][:12]}")
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()

"""Deterministic discrete-event simulator (paper §6.1, ``simulate.py``).

An event loop with callbacks scheduled at future simulated times, plus a
task/future/coroutine layer similar to Python's asyncio — but fully
deterministic: given a seed and parameters, every run executes the same
events in the same order.

Time is a float in **seconds** of simulated "true time". Nodes never read
this directly; they use :class:`repro.core.clock.BoundedClock`, which wraps
true time in an uncertainty interval.

Fast-path design notes (the simulator is the sweep bottleneck — see
``benchmarks/simperf.py`` for the tracked baseline):

* **Lazy-cancel timers**: :meth:`EventLoop.call_later_cancelable` returns a
  :class:`Timer` whose ``cancel()`` marks the heap entry dead in O(1); dead
  entries are skipped (reaped) when they reach the heap head instead of
  churning through a full event dispatch. RPC timeouts, reply-reaping and
  heartbeat parks all cancel their timers on the common (fast) path, which
  keeps the heap small and skips their no-op callbacks entirely.
* **Allocation-light wakeups**: ``Future._fire`` schedules ONE bound method
  per resolution instead of one closure per callback, and ``sleep`` uses
  ``Future._wake`` instead of a fresh lambda per sleep.
* **Instrumentation**: cheap counters (events popped, timers reaped, peak
  heap size) are maintained inline and exposed via :meth:`EventLoop.stats`
  so optimizations are measured, not guessed.

Everything above is *order-preserving*: the same (seed, params) pair pops
the same live events in the same sequence as the unoptimized loop, so PRNG
draw order — and therefore every simulated history — is unchanged.
"""

from __future__ import annotations

import heapq
import inspect
from typing import Any, Callable, Coroutine, Iterable, Optional


class Timer:
    """Handle for a cancelable heap entry.

    ``cancel()`` is O(1): it clears the callback; the entry itself is
    reaped lazily when it surfaces at the heap head (removing an arbitrary
    heap element would be O(n))."""

    __slots__ = ("_fn",)

    def __init__(self, fn: Callable[[], None]) -> None:
        self._fn = fn

    def cancel(self) -> None:
        self._fn = None

    @property
    def cancelled(self) -> bool:
        return self._fn is None


class EventLoop:
    """A deterministic event loop over simulated time."""

    __slots__ = ("_heap", "_seq", "now", "_stopped",
                 "events_popped", "timers_scheduled", "timers_reaped",
                 "peak_heap", "tracer")

    def __init__(self) -> None:
        self._heap: list[tuple[float, int, Any]] = []
        self._seq = 0  # tie-breaker: FIFO among same-deadline callbacks
        self.now: float = 0.0
        self._stopped = False
        # -- instrumentation (cheap enough to keep always-on) --
        self.events_popped = 0     # live events dispatched
        self.timers_scheduled = 0  # cancelable timers created
        self.timers_reaped = 0     # cancelled entries skipped at pop
        self.peak_heap = 0         # high-water mark of pending entries
        # flight recorder (repro.obs.trace.Tracer) or None. Default-off:
        # instrumentation sites across the stack guard on
        # ``loop.tracer is not None`` and make zero PRNG draws, so
        # untraced runs replay bit-identically and traced runs are
        # draw-order-neutral.
        self.tracer = None

    # -- scheduling ------------------------------------------------------
    def call_at(self, when: float, fn: Callable[[], None]) -> None:
        if when < self.now:
            when = self.now
        heap = self._heap
        heapq.heappush(heap, (when, self._seq, fn))
        self._seq += 1
        if len(heap) > self.peak_heap:
            self.peak_heap = len(heap)

    def call_later(self, delay: float, fn: Callable[[], None]) -> None:
        self.call_at(self.now + delay if delay > 0.0 else self.now, fn)

    def call_soon(self, fn: Callable[[], None]) -> None:
        self.call_at(self.now, fn)

    def call_at_cancelable(self, when: float, fn: Callable[[], None]) -> Timer:
        t = Timer(fn)
        self.call_at(when, t)
        self.timers_scheduled += 1
        return t

    def call_later_cancelable(self, delay: float,
                              fn: Callable[[], None]) -> Timer:
        return self.call_at_cancelable(
            self.now + delay if delay > 0.0 else self.now, fn)

    # -- running ---------------------------------------------------------
    def _next_time(self) -> Optional[float]:
        """Earliest *live* event time; reaps dead timers at the head."""
        heap = self._heap
        while heap:
            head = heap[0]
            fn = head[2]
            if fn.__class__ is Timer and fn._fn is None:
                heapq.heappop(heap)
                self.timers_reaped += 1
                continue
            return head[0]
        return None

    def _step(self) -> bool:
        heap = self._heap
        while heap:
            when, _, fn = heapq.heappop(heap)
            if fn.__class__ is Timer:
                fn = fn._fn
                if fn is None:
                    self.timers_reaped += 1
                    continue
            if when > self.now:
                self.now = when
            self.events_popped += 1
            fn()
            return True
        return False

    def run_until(self, deadline: float) -> None:
        """Run events with time <= deadline; advance clock to deadline."""
        while not self._stopped:
            t = self._next_time()
            if t is None or t > deadline:
                break
            self._step()
        if deadline > self.now:
            self.now = deadline

    def run_until_complete(self, fut: "Future", max_time: float = float("inf")):
        while not fut.done():
            t = self._next_time()
            if self._stopped or t is None or t > max_time:
                raise RuntimeError(
                    f"future not resolved by t={self.now:.6f} "
                    f"(heap={'empty' if t is None else 'future events'})"
                )
            self._step()
        return fut.result()

    def run(self, max_time: float = float("inf")) -> None:
        while not self._stopped:
            t = self._next_time()
            if t is None or t > max_time:
                break
            self._step()

    def stop(self) -> None:
        self._stopped = True

    def stats(self) -> dict:
        """Instrumentation snapshot (events dispatched, timer churn, heap
        high-water mark) — the raw inputs of ``benchmarks/simperf.py``."""
        return {
            "events_popped": self.events_popped,
            "timers_scheduled": self.timers_scheduled,
            "timers_reaped": self.timers_reaped,
            "pending": len(self._heap),
            "peak_heap": self.peak_heap,
            "now": self.now,
        }

    # -- coroutine layer --------------------------------------------------
    def create_task(self, coro: Coroutine) -> "Task":
        return Task(self, coro)

    def sleep(self, delay: float) -> "Future":
        f = Future(self)
        self.call_later(delay, f._wake)
        return f


class Future:
    """Awaitable one-shot result container bound to an :class:`EventLoop`."""

    __slots__ = ("loop", "_done", "_result", "_exc", "_callbacks")

    def __init__(self, loop: EventLoop) -> None:
        self.loop = loop
        self._done = False
        self._result: Any = None
        self._exc: Optional[BaseException] = None
        self._callbacks: list[Callable[["Future"], None]] = []

    def done(self) -> bool:
        return self._done

    def set_result(self, value: Any) -> None:
        if self._done:
            raise RuntimeError("future already resolved")
        self._done = True
        self._result = value
        if self._callbacks:
            self.loop.call_soon(self._run_callbacks)

    def set_exception(self, exc: BaseException) -> None:
        if self._done:
            raise RuntimeError("future already resolved")
        self._done = True
        self._exc = exc
        if self._callbacks:
            self.loop.call_soon(self._run_callbacks)

    def _wake(self) -> None:
        """Resolve with None unless already resolved (sleep/timeout path)."""
        if not self._done:
            self.set_result(None)

    def _run_callbacks(self) -> None:
        # One scheduled event runs every callback registered at resolution
        # time, in registration order — equivalent to scheduling each
        # callback individually (their seq numbers were contiguous), but
        # with a single heap entry and no per-callback closure.
        cbs, self._callbacks = self._callbacks, []
        for cb in cbs:
            cb(self)

    def add_done_callback(self, cb: Callable[["Future"], None]) -> None:
        if self._done:
            self.loop.call_soon(lambda: cb(self))
        else:
            self._callbacks.append(cb)

    def result(self) -> Any:
        if not self._done:
            raise RuntimeError("future not done")
        if self._exc is not None:
            raise self._exc
        return self._result

    def __await__(self):
        if not self._done:
            yield self
        return self.result()


class Task(Future):
    """Drives a coroutine on the event loop. Awaitable like a Future."""

    __slots__ = ("_coro", "_cancelled")

    def __init__(self, loop: EventLoop, coro: Coroutine) -> None:
        super().__init__(loop)
        assert inspect.iscoroutine(coro), coro
        self._coro = coro
        self._cancelled = False
        loop.call_soon(self._start)

    def cancel(self) -> None:
        self._cancelled = True

    def _start(self) -> None:
        self._advance(None, None)

    def _resume(self, fut: "Future") -> None:
        exc = fut._exc
        if exc is not None:
            self._advance(None, exc)
        else:
            self._advance(fut._result, None)

    def _advance(self, value: Any, exc: Optional[BaseException]) -> None:
        if self._done:
            return
        if self._cancelled:
            self._coro.close()
            if not self._done:
                self.set_exception(CancelledError())
            return
        try:
            if exc is not None:
                awaited = self._coro.throw(exc)
            else:
                awaited = self._coro.send(value)
        except StopIteration as stop:
            self.set_result(stop.value)
            return
        except BaseException as e:  # noqa: BLE001 - propagate into the future
            self.set_exception(e)
            return
        assert isinstance(awaited, Future), f"can only await Futures, got {awaited!r}"
        if awaited._done:
            self.loop.call_soon(lambda: self._resume(awaited))
        else:
            awaited._callbacks.append(self._resume)


class CancelledError(Exception):
    pass


class TimeoutError_(Exception):
    pass


def wait_for(fut: Future, timeout: float) -> Future:
    """Await ``fut`` with a simulated-time timeout.

    Returns a Future that resolves with ``fut``'s result, or raises
    :class:`TimeoutError_` after ``timeout`` simulated seconds. The
    timeout timer is *cancelled the moment the future resolves* — the
    common fast path — so resolved RPCs leave no dead heap entry parked
    until their deadline."""
    loop = fut.loop
    waiter = Future(loop)

    def _on_done(f: Future) -> None:
        if not waiter._done:
            timer.cancel()
            if f._exc is not None:
                waiter.set_exception(f._exc)
            else:
                waiter.set_result(f._result)

    def _on_timeout() -> None:
        if not waiter._done:
            waiter.set_exception(TimeoutError_(f"timed out after {timeout}s"))

    fut.add_done_callback(_on_done)
    timer = loop.call_later_cancelable(timeout, _on_timeout)
    return waiter


async def gather(futs: Iterable[Future]) -> list:
    return [await f for f in futs]


class Event:
    """An asyncio.Event lookalike over simulated time."""

    __slots__ = ("loop", "_set", "_waiters")

    def __init__(self, loop: EventLoop) -> None:
        self.loop = loop
        self._set = False
        self._waiters: list[Future] = []

    def is_set(self) -> bool:
        return self._set

    def set(self) -> None:
        self._set = True
        ws, self._waiters = self._waiters, []
        for w in ws:
            if not w.done():
                w.set_result(None)

    def clear(self) -> None:
        self._set = False

    async def wait(self) -> None:
        if self._set:
            return
        f = Future(self.loop)
        self._waiters.append(f)
        await f


class Condition:
    """Broadcast wakeup: tasks await a predicate re-checked on notify."""

    __slots__ = ("loop", "_waiters")

    def __init__(self, loop: EventLoop) -> None:
        self.loop = loop
        # (future, timeout Timer or None) pairs; the timer is cancelled on
        # notify so an idle leader's heartbeat parks don't pile dead
        # entries onto the heap
        self._waiters: list[tuple[Future, Optional[Timer]]] = []

    def notify_all(self) -> None:
        ws, self._waiters = self._waiters, []
        for w, timer in ws:
            if timer is not None:
                timer.cancel()
            if not w.done():
                w.set_result(None)

    async def wait(self, timeout: Optional[float] = None) -> None:
        """Wait for the next notify_all; with ``timeout``, give up after that
        much simulated time. The condition owns the timeout path so that a
        timed-out waiter is removed from the waiter list immediately — an
        idle leader parks here on every heartbeat tick, and leaving resolved
        futures behind until the next notify_all would grow the list without
        bound."""
        f = Future(self.loop)
        if timeout is None:
            self._waiters.append((f, None))
        else:
            def _expire() -> None:
                if not f.done():
                    try:
                        self._waiters.remove(entry)
                    except ValueError:
                        pass
                    f.set_result(None)
            entry = (f, self.loop.call_later_cancelable(timeout, _expire))
            self._waiters.append(entry)
        await f

    async def wait_until(self, predicate: Callable[[], bool]) -> None:
        while not predicate():
            await self.wait()

"""Shared helpers for the paper-figure benchmarks."""

from __future__ import annotations

from repro.consistency import benchmark_configs
from repro.core import RaftParams, SimParams, run_workload

# One row per registered consistency policy, plus the paper's LeaseGuard
# ablation variants (Figs. 7/9) — derived from the policy registry, so a
# newly registered policy shows up in every figure automatically.
CONFIGS = benchmark_configs()


def crash_leader_at(t: float):
    def script(cluster):
        def crash():
            ldr = cluster.leader()
            if ldr is not None and ldr.alive:
                ldr.crash()
        cluster.loop.call_later(t, crash)
    return script


def freeze_then_crash_at(t_freeze: float, t_crash: float):
    """Engineer a limbo region (paper §6.6): the leader keeps committing but
    stops advertising commitIndex, then crashes."""
    def script(cluster):
        def freeze():
            ldr = cluster.leader()
            if ldr is not None and ldr.alive:
                ldr.freeze_commits()

        def crash():
            ldr = cluster.leader()
            if ldr is not None and ldr.alive:
                ldr.crash()
        cluster.loop.call_later(t_freeze, freeze)
        cluster.loop.call_later(t_crash, crash)
    return script


def emit(rows: list[dict]) -> None:
    if not rows:
        return
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(_fmt(r[c]) for c in cols))


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)

"""No consistency mechanism: leader-local reads with no lease or barrier.

The paper's lower-bound baseline (§6): reads are as fast as possible and
as wrong as possible — a deposed leader that has not yet heard of its
successor happily serves stale data. Useful to bound the cost every real
mechanism pays.
"""

from __future__ import annotations

from ..core.raft import ReadResult
from .base import ConsistencyPolicy


class InconsistentPolicy(ConsistencyPolicy):
    name = "inconsistent"

    async def gate_read(self, key: str) -> ReadResult:
        n = self.node
        if not n.is_leader():
            return ReadResult(False, error="not_leader")
        return ReadResult(True, list(n.data.get(key, [])),
                          execution_ts=n.loop.now)

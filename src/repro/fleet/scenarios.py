"""Named fleet scenarios: data-plane chaos, control-plane chaos, and the
combined schedules where both strike at once.

Same registry idiom as :mod:`repro.faults.scenarios`; every scenario
here is within the crash-stop fault model (``expect_safe=True``), so the
fleet matrix asserts ZERO lineage violations for every consistent policy
— the ``inconsistent`` policy is the positive control that must get
flagged. Timings assume the default ``FleetParams.duration`` of 4s."""

from __future__ import annotations

from typing import Callable, Optional

from ..faults.base import Window
from ..faults.library import CrashRestart, LeaderNemesis, MajorityMinority
from .faults import (CheckpointStorm, ChiefKill, FleetScenario, WorkerCrash,
                     WorkerStraggler)

FLEET_SCENARIOS: dict[str, Callable[[], FleetScenario]] = {}


def fleet_scenario(name: str, expect_safe: bool = True,
                   description: str = "",
                   raft_overrides: Optional[dict] = None,
                   meta: Optional[dict] = None):
    def deco(factory: Callable[[], list[Window]]):
        def build() -> FleetScenario:
            return FleetScenario(name, factory(), expect_safe=expect_safe,
                                 description=description,
                                 raft_overrides=raft_overrides, meta=meta)

        build.scenario_name = name
        build.expect_safe = expect_safe
        build.description = description
        build.raft_overrides = dict(raft_overrides or {})
        FLEET_SCENARIOS[name] = build
        return build

    return deco


def build_fleet_scenario(name: str) -> FleetScenario:
    try:
        return FLEET_SCENARIOS[name]()
    except KeyError:
        raise ValueError(f"unknown fleet scenario {name!r}; registered: "
                         f"{sorted(FLEET_SCENARIOS)}") from None


def fleet_scenario_names() -> list[str]:
    return list(FLEET_SCENARIOS)


# ------------------------------------------------------- data-plane only
@fleet_scenario("calm", description="no faults; baseline poll/commit load")
def _calm() -> list[Window]:
    return []


@fleet_scenario("worker_crashes",
                description="two crash waves across the worker pool")
def _worker_crashes() -> list[Window]:
    return [Window(WorkerCrash("fraction:0.3", downtime=0.6), at=0.8),
            Window(WorkerCrash("fraction:0.2", downtime=0.5), at=2.2)]


@fleet_scenario("straggler_band",
                description="a quarter of the fleet runs 4x slow for 2s")
def _straggler_band() -> list[Window]:
    return [Window(WorkerStraggler("fraction:0.25", factor=4.0),
                   at=0.5, until=2.5)]


@fleet_scenario("chief_kill",
                description="kill the chief once; successor must take over")
def _chief_kill() -> list[Window]:
    return [Window(ChiefKill(downtime=0.8), at=1.0)]


@fleet_scenario("chief_nemesis",
                description="chase and kill every newly elected chief")
def _chief_nemesis() -> list[Window]:
    return [Window(ChiefKill(downtime=0.4, period=0.9), at=0.8, until=3.4)]


@fleet_scenario("checkpoint_storm",
                description="manifest every step + a crash wave mid-storm")
def _checkpoint_storm() -> list[Window]:
    return [Window(CheckpointStorm(every=1), at=0.5, until=3.0),
            Window(WorkerCrash("fraction:0.2", downtime=0.5), at=1.5)]


# --------------------------------------------- combined control + data
@fleet_scenario("leader_crash_mid_commit",
                description="Raft leader crashes twice during a "
                            "checkpoint storm: commits caught in flight")
def _leader_crash_mid_commit() -> list[Window]:
    return [Window(CheckpointStorm(every=1), at=0.5, until=3.0),
            Window(CrashRestart("leader", downtime=0.4), at=1.0),
            Window(CrashRestart("leader", downtime=0.4), at=2.2)]


@fleet_scenario("chief_and_leader_die",
                description="chief and Raft leader die at the same instant")
def _chief_and_leader_die() -> list[Window]:
    return [Window(ChiefKill(downtime=0.8), at=1.0),
            Window(CrashRestart("leader", downtime=0.4), at=1.0)]


@fleet_scenario("leader_nemesis_fleet",
                description="control-plane leader nemesis under a "
                            "full training fleet")
def _leader_nemesis_fleet() -> list[Window]:
    return [Window(LeaderNemesis(period=0.6, downtime=0.25),
                   at=0.6, until=3.2)]


@fleet_scenario("partition_churn",
                description="majority/minority split while a crash wave "
                            "forces restores mid-partition")
def _partition_churn() -> list[Window]:
    return [Window(MajorityMinority(leader_in_minority=True),
                   at=1.0, until=2.0),
            Window(WorkerCrash("fraction:0.3", downtime=0.5), at=1.2)]

"""Corruption tier: end-to-end checksums on AppendEntries.

The adversarial positive control for the whole detection stack: the same
corrupting storm schedule must (a) produce client-visible
linearizability violations when checksums are OFF — proving the fault
has real teeth and the checker catches it — and (b) produce zero
violations when checksums are ON, with the drop counter showing the
corrupted messages were actually intercepted, not just absent."""

import pytest
from dataclasses import replace

from repro.core import (LinearizabilityError, RaftParams, ReadMode,
                        SimParams, build_cluster, check_linearizability,
                        run_workload)
from repro.core.raft import (AppendEntries, LogEntry, append_digest,
                             entry_checksum)
from repro.faults import build_scenario

# Small keyspace + write-heavy mix so reads revisit corrupted keys: with
# the default sparse keyspace a poisoned entry is rarely re-read and the
# divergence stays silent.
SIM = dict(n_keys=25, write_fraction=0.5, sim_duration=1.5,
           interarrival=3e-3)


def storm_run(seed: int, *, checksums: bool):
    sc = build_scenario("corrupt_entries_unchecked")  # storm, no overrides
    raft = RaftParams(read_mode=ReadMode.LEASEGUARD, election_timeout=0.3,
                      election_jitter=0.1, heartbeat_interval=0.03,
                      lease_duration=0.6, rpc_timeout=0.15,
                      entry_checksums=checksums)
    sim = SimParams(seed=seed, **SIM)
    return run_workload(raft, sim, fault_script=sc.install, check=False,
                        settle_time=1.5)


# ------------------------------------------------------------- unit level
def _leader_follower():
    raft = RaftParams(read_mode=ReadMode.LEASEGUARD, election_timeout=0.5,
                      lease_duration=2.0, entry_checksums=True)
    c = build_cluster(raft, SimParams(seed=3))
    ldr = c.wait_for_leader()
    f = next(n for n in c.nodes.values() if n is not ldr)
    return c, ldr, f


def test_checksums_stamped_and_verified_round_trip():
    c, ldr, f = _leader_follower()
    e = LogEntry(ldr.term, "k", 1, ldr.log[ldr.last_log_index].interval)
    e.checksum = entry_checksum(e.term, e.key, e.value)
    msg = ldr._make_append(ldr.last_log_index, [e], ldr.commit_index)
    assert msg.checksum == append_digest(msg)
    reply = f._handle_append(ldr.id, msg)
    assert reply is not None and reply.success
    assert f.checksum_drops == 0


@pytest.mark.parametrize("mutate", [
    lambda m: replace(m, entries=[replace(m.entries[0], value=999)]),
    lambda m: replace(m, prev_index=m.prev_index - 1),
    lambda m: replace(m, prev_term=m.prev_term + 1),
    lambda m: replace(m, leader_commit=m.leader_commit + 2),
], ids=["payload", "prev_index", "prev_term", "commit_index"])
def test_handle_append_drops_mutated_message(mutate):
    """Any single-field in-flight mutation breaks the digest: the
    follower drops the message before touching ANY state — no reply, no
    term bump, no log change."""
    c, ldr, f = _leader_follower()
    e = LogEntry(ldr.term, "k", 1, ldr.log[ldr.last_log_index].interval)
    e.checksum = entry_checksum(e.term, e.key, e.value)
    msg = ldr._make_append(ldr.last_log_index, [e], ldr.commit_index)
    log_before, term_before = list(f.log), f.term
    reply = f._handle_append(ldr.id, mutate(msg))
    assert reply is None
    assert f.checksum_drops == 1
    assert f.log == log_before and f.term == term_before


def test_missing_checksum_rejected_when_required():
    """A message with no digest at all (e.g. from a sender that skipped
    ``_make_append``) is dropped, not trusted."""
    c, ldr, f = _leader_follower()
    bare = AppendEntries(ldr.term, ldr.id, ldr.last_log_index,
                         ldr.log[ldr.last_log_index].term, [],
                         ldr.commit_index)
    assert bare.checksum is None
    assert f._handle_append(ldr.id, bare) is None
    assert f.checksum_drops == 1


# -------------------------------------------------- end-to-end control
STORM_SEEDS = range(6)


@pytest.mark.slow
@pytest.mark.parametrize("seed", STORM_SEEDS)
def test_unchecked_corruption_is_client_visible(seed):
    """Positive control: with checksums OFF the corrupt storm poisons a
    follower's log, the mid-storm leader crash promotes it, and the
    divergence surfaces as a linearizability violation. If this ever
    stops failing-by-design, the corruption fault (or the checker) has
    lost its teeth."""
    res = storm_run(seed, checksums=False)
    with pytest.raises(LinearizabilityError):
        check_linearizability(res.history)
    assert res.raft_stats["checksum_drops"] == 0   # nothing was detected


@pytest.mark.slow
@pytest.mark.parametrize("seed", STORM_SEEDS)
def test_checked_corruption_stays_linearizable(seed):
    """Same storm, same seeds, checksums ON: every corrupted message is
    detected-and-dropped and the history stays linearizable."""
    res = storm_run(seed, checksums=True)
    assert check_linearizability(res.history) > 0
    assert res.raft_stats["checksum_drops"] > 0    # drops actually fired
    assert res.reads_ok + res.writes_ok > 0        # still available

"""Conformance suite for the pluggable consistency layer: every policy in
the registry must elect a leader, commit writes, serve linearizable reads
(checked via core/checker.py) and survive a leader crash; plus
policy-specific properties — ReadIndex's batched barrier beats QUORUM's
per-read round, and follower reads serve locally off one leader RPC."""

import pytest

from repro.consistency import (REGISTRY, FollowerReadPolicy,
                               benchmark_configs, make_policy,
                               resolve_read_mode)
from repro.core import (ClientLogEntry, RaftParams, ReadMode, SimParams,
                        build_cluster, check_linearizability, run_workload)
from repro.core.client import Workload

MODES = list(REGISTRY)
MODE_IDS = [m.value for m in MODES]


def run(c, coro):
    return c.loop.run_until_complete(c.loop.create_task(coro))


def crash_and_wait_new_leader(c, ldr, max_time=5.0):
    ldr.crash()
    deadline = c.loop.now + max_time
    while c.loop.now < deadline:
        c.loop.run_until(c.loop.now + 0.05)
        new = next((n for n in c.nodes.values()
                    if n.is_leader() and n is not ldr), None)
        if new is not None:
            return new
    raise RuntimeError("no new leader elected")


# --------------------------------------------------------- registry sanity
def test_registry_names_match_read_modes():
    for mode, cls in REGISTRY.items():
        assert cls.name == mode.value
        assert resolve_read_mode(mode.value) is mode
        assert resolve_read_mode(cls) is mode
    # benchmark configs cover every registered policy
    modes_covered = {cfg["read_mode"] for cfg in benchmark_configs().values()}
    assert modes_covered == set(REGISTRY)


def test_node_policy_matches_read_mode():
    for mode, cls in REGISTRY.items():
        c = build_cluster(RaftParams(read_mode=mode), SimParams())
        c.loop.run_until(0.01)  # start the node tasks before teardown
        assert all(type(n.policy) is cls for n in c.nodes.values())


# ------------------------------------------------------------- conformance
@pytest.mark.parametrize("mode", MODES, ids=MODE_IDS)
def test_policy_write_read_failover_conformance(mode):
    raft = RaftParams(read_mode=mode, election_timeout=0.5,
                      election_jitter=0.1, heartbeat_interval=0.05,
                      lease_duration=1.0)
    c = build_cluster(raft, SimParams(seed=3))
    ldr = c.wait_for_leader()

    h = []
    t0 = c.loop.now
    w = run(c, ldr.client_write("k", 1))
    assert w.ok
    h.append(ClientLogEntry("ListAppend", t0, w.entry.execution_ts,
                            c.loop.now, "k", 1, True))
    c.loop.run_until(c.loop.now + 0.2)
    t1 = c.loop.now
    r = run(c, ldr.client_read("k"))
    assert r.ok and r.value == [1]
    h.append(ClientLogEntry("Read", t1, r.execution_ts, c.loop.now,
                            "k", r.value, True))
    assert check_linearizability(h) == len(h)

    # leader crash -> failover -> once any inherited lease has expired,
    # the policy must serve writes and reads again
    new = crash_and_wait_new_leader(c, ldr)
    c.loop.run_until(c.loop.now + raft.delta + 0.5)
    assert run(c, new.client_write("k", 2)).ok
    r2 = run(c, new.client_read("k"))
    assert r2.ok and r2.value == [1, 2]


@pytest.mark.parametrize("mode", MODES, ids=MODE_IDS)
def test_policy_linearizable_under_leader_crash(mode):
    """Workload + crash + full history check, per policy. INCONSISTENT is
    exempt from the check (being non-linearizable is its point)."""
    raft = RaftParams(read_mode=mode, election_timeout=0.3,
                      election_jitter=0.1, heartbeat_interval=0.03,
                      lease_duration=0.6)
    sim = SimParams(
        seed=11, sim_duration=1.0, interarrival=2e-3,
        follower_read_fraction=0.4 if mode is ReadMode.FOLLOWER_READ else 0.0)

    def script(cluster):
        cluster.loop.call_later(
            0.4, lambda: cluster.leader() and cluster.leader().crash())

    res = run_workload(raft, sim, fault_script=script,
                       check=mode is not ReadMode.INCONSISTENT,
                       settle_time=2.0)
    if mode is not ReadMode.INCONSISTENT:
        assert res.linearizable_ops > 0
    assert res.reads_ok + res.writes_ok > 0


# ------------------------------------------------------- ReadIndex batching
def test_readindex_fewer_quorum_rounds_than_quorum():
    """ReadIndex's shared barrier must cost measurably fewer messages than
    QUORUM's per-read round on a read-heavy workload."""
    counts = {}
    ok_counts = {}
    for mode in (ReadMode.QUORUM, ReadMode.READ_INDEX):
        raft = RaftParams(read_mode=mode)
        # 1 ms one-way latency: each barrier round spans many arrivals, the
        # regime where sharing the round pays off
        sim = SimParams(sim_duration=1.0, interarrival=300e-6, seed=13,
                        write_fraction=0.1, one_way_latency_mean=1e-3,
                        one_way_latency_variance=1e-6)
        c = build_cluster(raft, sim)
        c.wait_for_leader()
        w = Workload(c.loop, c.nodes, c.directory, c.prng.fork(999), sim)
        base = c.net.messages_sent
        c.loop.create_task(w.run(sim.sim_duration))
        c.loop.run_until(c.loop.now + sim.sim_duration + 0.5)
        counts[mode] = c.net.messages_sent - base
        ok_counts[mode] = sum(1 for op in w.history if op.success)
        assert ok_counts[mode] > 500
    # both serve comparable load, but ReadIndex amortizes the barrier
    assert counts[ReadMode.READ_INDEX] < 0.5 * counts[ReadMode.QUORUM], \
        (counts, ok_counts)


def test_readindex_no_stale_read_after_failover():
    """Regression (dissertation §6.4 step 1): a fresh leader must not serve
    ReadIndex reads before an own-term entry commits — its commitIndex can
    lag writes the old leader acked, and serving the pre-barrier state is a
    stale read. Seed 6 with a tiny key space used to trip the checker."""
    raft = RaftParams(read_mode=ReadMode.READ_INDEX, election_timeout=0.3,
                      election_jitter=0.1, heartbeat_interval=0.03)

    def script(cluster):
        cluster.loop.call_later(
            0.4, lambda: cluster.leader() and cluster.leader().crash())

    for seed in (6, 43, 77):
        sim = SimParams(seed=seed, sim_duration=1.0, interarrival=2e-3,
                        one_way_latency_mean=2e-3,
                        one_way_latency_variance=4e-6, n_keys=5)
        res = run_workload(raft, sim, fault_script=script, check=True,
                           settle_time=2.0)
        assert res.linearizable_ops > 0


# --------------------------------------------------------- follower reads
def make_follower_cluster(**kw):
    raft = RaftParams(read_mode=ReadMode.FOLLOWER_READ, lease_duration=2.0,
                      election_timeout=0.5, **kw)
    return build_cluster(raft, SimParams(seed=5))


def test_follower_read_serves_locally_after_leader_grant():
    c = make_follower_cluster()
    ldr = c.wait_for_leader()
    assert run(c, ldr.client_write("x", 1)).ok
    c.loop.run_until(c.loop.now + 0.2)  # follower applies the entry
    follower = next(n for n in c.nodes.values() if n is not ldr)
    before = c.net.messages_sent
    res = run(c, follower.client_read("x"))
    assert res.ok and res.value == [1]
    # exactly one read-index RPC to the leader (replies are not counted
    # by messages_sent); compare: a quorum read costs one call per peer
    assert c.net.messages_sent - before == 1


def test_follower_read_waits_for_apply():
    """A freshly written key is readable at a follower even before the
    heartbeat that advances the follower's commit index: the follower
    blocks on the leader-issued read index, then serves."""
    c = make_follower_cluster()
    ldr = c.wait_for_leader()
    assert run(c, ldr.client_write("x", 1)).ok
    follower = next(n for n in c.nodes.values() if n is not ldr)
    res = run(c, follower.client_read("x"))
    assert res.ok and res.value == [1]


def test_follower_read_leader_still_serves_leaseguard_reads():
    c = make_follower_cluster()
    ldr = c.wait_for_leader()
    assert isinstance(ldr.policy, FollowerReadPolicy)
    assert run(c, ldr.client_write("x", 1)).ok
    c.loop.run_until(c.loop.now + 0.1)
    before = c.net.messages_sent
    res = run(c, ldr.client_read("x"))
    assert res.ok and res.value == [1]
    assert c.net.messages_sent == before  # leader path is zero-roundtrip


def test_follower_read_rejected_for_limbo_key():
    """The leader's read-index barrier applies the §3.3 limbo check, so a
    follower cannot observe a key the new leader may not serve itself."""
    c = make_follower_cluster()
    ldr = c.wait_for_leader()
    assert run(c, ldr.client_write("safe", 1)).ok
    c.loop.run_until(c.loop.now + 0.3)
    ldr.freeze_commits()
    assert run(c, ldr.client_write("limbo_key", 2)).ok
    t_last = c.loop.now
    new = crash_and_wait_new_leader(c, ldr)
    assert c.loop.now < t_last + 2.0, "election must finish inside the lease"
    assert new._commit_gate_blocked()
    follower = next(n for n in c.nodes.values()
                    if n is not new and n.alive)
    res = run(c, follower.client_read("limbo_key"))
    assert not res.ok and res.error == "limbo"
    res = run(c, follower.client_read("safe"))
    assert res.ok and res.value == [1]

"""hymba-1.5b — hybrid: parallel attention + mamba heads in each layer,
ssm_state=16, sliding-window attention on most layers.
[arXiv:2411.13676; hf]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    hybrid_ssm=True,
    ssm_state=16,
    grad_accum=2,
    sliding_window=1024,      # hymba uses SWA + meta tokens; window 1k
    source="arXiv:2411.13676",
)

"""Open-loop workload clients + history records (paper §6.1-6.3).

Each client performs one operation against the node it believes is the
leader (client-server latency is zero, as in the paper's Q1/Q2 setups).
Workload generators are *open loop*: arrivals follow a Poisson process
regardless of response latency [45].
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from .params import SimParams
from .prob import PRNG, Zipf
from .raft import Node
from .simulate import EventLoop


@dataclass(slots=True)
class ClientLogEntry:
    """One operation in the history (paper §6.2)."""
    op_type: str                 # "ListAppend" | "Read"
    start_ts: float
    execution_ts: Optional[float]
    end_ts: float
    key: str
    value: object                # appended value, or list returned by Read
    success: bool
    error: str = ""


class Directory:
    """Shared leader hint: nodes report leadership; clients consult it."""

    def __init__(self) -> None:
        self.leader_id: Optional[int] = None
        self.leader_term = -1
        #: bumps on every leadership announcement (even stale-term ones);
        #: lets ``Cluster.wait_for_leader`` block on the event instead of
        #: polling the node set every 10 ms
        self.announcements = 0

    def on_leader(self, node_id: int, term: int) -> None:
        self.announcements += 1
        if term >= self.leader_term:
            self.leader_id = node_id
            self.leader_term = term


class Workload:
    def __init__(self, loop: EventLoop, nodes: dict[int, Node],
                 directory: Directory, prng: PRNG, sim: SimParams) -> None:
        self.loop = loop
        self.nodes = nodes
        self.directory = directory
        self.prng = prng
        self.sim = sim
        self.zipf = Zipf(sim.n_keys, sim.zipf_a) if sim.zipf_a > 0 else None
        self.history: list[ClientLogEntry] = []
        self._entry_refs: list = []   # (record, LogEntry) for late commits
        self._value_seq = 0
        self._stop = False

    def stop(self) -> None:
        self._stop = True

    def finalize(self) -> list[ClientLogEntry]:
        """Refresh append commit times from the shared log entries."""
        for rec, entry in self._entry_refs:
            rec.execution_ts = entry.execution_ts
        return self.history

    def _pick_key(self) -> str:
        if self.zipf is not None:
            return f"k{self.zipf.sample(self.prng)}"
        return f"k{self.prng.randint(0, self.sim.n_keys - 1)}"

    async def run(self, duration: float) -> None:
        """Spawn one-op clients by Poisson arrivals for ``duration`` seconds."""
        end = self.loop.now + duration
        while self.loop.now < end and not self._stop:
            gap = self.prng.exponential(self.sim.interarrival)
            await self.loop.sleep(gap)
            if self.loop.now >= end or self._stop:
                break
            is_write = self.prng.random() < self.sim.write_fraction
            key = self._pick_key()
            if is_write:
                self._value_seq += 1
                self.loop.create_task(self._one_write(key, self._value_seq))
            else:
                self.loop.create_task(self._one_read(key))

    def _leader_node(self) -> Optional[Node]:
        lid = self.directory.leader_id
        if lid is None:
            return None
        return self.nodes.get(lid)

    async def _one_write(self, key: str, value: int) -> None:
        start = self.loop.now
        node = self._leader_node()
        if node is None or not node.alive:
            self.history.append(ClientLogEntry(
                "ListAppend", start, None, self.loop.now, key, value, False,
                "no_leader"))
            return
        res = await node.client_write(key, value)
        # Execution time = when the write was committed on the leader (§6.2).
        # We hold the shared LogEntry object: if the write commits *later*
        # (e.g. after a failover), finalize() picks up its commit time, which
        # resolves the paper's failed-append ambiguity omnisciently.
        rec = ClientLogEntry(
            "ListAppend", start,
            res.entry.execution_ts if res.entry is not None else None,
            self.loop.now, key, value, res.ok, res.error)
        self.history.append(rec)
        if res.entry is not None:
            self._entry_refs.append((rec, res.entry))

    def _read_target(self) -> Optional[Node]:
        """Usually the leader; with ``follower_read_fraction`` > 0, a random
        live non-leader replica (for policies that can serve follower reads).
        The fraction==0 path makes no PRNG draws, so existing seeds replay
        identically."""
        leader = self._leader_node()
        frac = self.sim.follower_read_fraction
        if frac <= 0.0 or self.prng.random() >= frac:
            return leader
        others = [n for _, n in sorted(self.nodes.items())
                  if n.alive and n is not leader]
        if not others:
            return leader
        return others[self.prng.randint(0, len(others) - 1)]

    async def _one_read(self, key: str) -> None:
        start = self.loop.now
        node = self._read_target()
        if node is None or not node.alive:
            self.history.append(ClientLogEntry(
                "Read", start, None, self.loop.now, key, None, False,
                "no_leader"))
            return
        res = await node.client_read(key)
        self.history.append(ClientLogEntry(
            "Read", start, res.execution_ts if res.ok else None,
            self.loop.now, key, res.value, res.ok, res.error))

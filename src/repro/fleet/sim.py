"""The fleet harness: N training workers + one Raft replica set on ONE
deterministic event loop.

``run_fleet(raft, sim, fleet_params, scenario)`` mirrors
``core.runner.run_workload``: build the cluster, elect a leader, install
the (fleet) scenario, start the workers, run for ``duration`` plus a
settle window, then audit omnisciently — the lineage checks off the
surviving replicas' Raft log, steps-lost / recovery-time around chief
and leader deaths, and the control-plane message load per worker step
(clients call replica methods directly, so every Network message is
intra-replica-set coordination: the quorum-poll bottleneck measured
exactly).

Everything is deterministic per (RaftParams, SimParams, FleetParams,
scenario): worker PRNGs fork off the cluster root *after* it is built,
so fleet runs never perturb the replica set's replay."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import json

from ..coord.kvstore import CoordClient
from ..coord.registry import REPORTS_KEY, straggler_flags_from
from ..core import RaftParams, SimParams, build_cluster
from ..core.runner import Cluster
from ..faults.base import Scenario
from .lineage import check_lineage, extract_fleet_log
from .worker import Worker


@dataclass
class FleetParams:
    n_workers: int = 8
    step_time: float = 0.02         # simulated seconds per training step
    step_jitter: float = 0.25       # uniform per-step jitter fraction
    ckpt_every: int = 5             # chief commits a manifest every N own steps
    poll_timeout: float = 0.15      # per-step checkpoint poll budget
    op_timeout: float = 0.4         # registry / commit / restore op budget
    retry_delay: float = 0.05
    heartbeat_period: float = 0.25
    report_every: int = 10          # step-time report cadence (steps)
    worker_ttl: float = 0.6         # liveness TTL for chief election
    chief_check_period: float = 0.18
    duration: float = 4.0
    settle: float = 1.0
    #: fraction of reads served by a random (possibly stale) replica —
    #: how clients of the ``inconsistent`` policy actually behave
    read_any_fraction: float = 0.0


class Fleet:
    """Owns the workers and the run-wide traces the checker consumes."""

    def __init__(self, cluster: Cluster, params: FleetParams) -> None:
        self.cluster = cluster
        self.p = params
        self.loop = cluster.loop
        self.running = False
        self.t0 = cluster.loop.now
        self.total_steps = 0
        self.ckpt_override: Optional[int] = None    # CheckpointStorm hook
        self.restores: list[dict] = []
        self.commit_log: list[tuple[float, int, bool]] = []
        self.last_ok_commit_step = -1
        self.chief_deaths: list[dict] = []
        self.trace: list[tuple[float, str]] = []
        self.workers: dict[str, Worker] = {}
        # forked AFTER build_cluster: the replica set's draw order (and
        # therefore every committed artifact) replays untouched
        for i in range(params.n_workers):
            w = Worker(self, i, cluster.prng.fork(1000 + i),
                       CoordClient(cluster, prng=cluster.prng.fork(1500 + i),
                                   op_timeout=params.op_timeout,
                                   retry_delay=params.retry_delay,
                                   read_any_fraction=params.read_any_fraction))
            self.workers[w.wid] = w

    def ckpt_every(self) -> int:
        return self.ckpt_override or self.p.ckpt_every

    def worker_order(self, wid: str) -> int:
        w = self.workers.get(wid)
        return w.index if w is not None else 10 ** 9

    def ordered_workers(self) -> list[Worker]:
        return sorted(self.workers.values(), key=lambda w: w.index)

    def note(self, event: str) -> None:
        self.trace.append((self.loop.now, event))
        tr = self.loop.tracer
        if tr is not None:
            tr.emit("fleet", op="note", label=event)

    # -- traces ------------------------------------------------------------
    def record_restore(self, wid: str, kind: str, t_start: float,
                       t_end: float, manifest: Optional[dict],
                       gen: int) -> None:
        self.restores.append({"wid": wid, "kind": kind, "t_start": t_start,
                              "t_end": t_end, "manifest": manifest,
                              "gen": gen})
        tr = self.loop.tracer
        if tr is not None:
            step = manifest["step"] if manifest else -1
            tr.emit("fleet", op="restore", wid=wid, kind=kind, step=step)

    def record_commit(self, t: float, step: int, ok: bool) -> None:
        self.commit_log.append((t, step, ok))
        if ok and step > self.last_ok_commit_step:
            self.last_ok_commit_step = step
        tr = self.loop.tracer
        if tr is not None:
            tr.emit("fleet", op="manifest", step=step, ok=ok)

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> None:
        self.running = True
        self.t0 = self.loop.now
        for w in self.ordered_workers():
            w.start()

    def crash_worker(self, wid: str,
                     downtime: Optional[float] = None) -> bool:
        w = self.workers[wid]
        if not w.alive:
            return False
        if w.is_chief:
            self.chief_deaths.append({
                "t": self.loop.now, "wid": wid, "epoch": w.epoch,
                "local_step": w.local_step,
                "committed_step": self.last_ok_commit_step})
        self.note(f"worker {wid} crashed"
                  + (" (chief)" if w.is_chief else ""))
        w.crash()
        if downtime is not None:
            self.loop.call_later(downtime, lambda: self.start_worker(wid))
        return True

    def start_worker(self, wid: str) -> None:
        w = self.workers[wid]
        if w.alive or not self.running:
            return
        self.note(f"worker {wid} restarts")
        w.start()


@dataclass
class FleetResult:
    violations: list[dict]
    total_steps: int
    n_claims: int
    n_manifests: int
    n_valid_manifests: int
    restores: int
    stale_polls: int
    polls_ok: int
    polls_failed: int
    commits_ok: int
    commits_failed: int
    messages: int                   # network messages during the run
    messages_per_step: float
    chief_deaths: list[dict]        # each with steps_lost / recovery_time
    leader_recoveries: list[float]  # commit-recovery time per leader death
    max_commit_gap: float
    straggler_flags: dict = field(default_factory=dict)
    restores_detail: list = field(default_factory=list)
    trace: list = field(default_factory=list)
    events: list = field(default_factory=list)  # flight-recorder events

    def summarize(self) -> dict:
        return {
            "violations": len(self.violations),
            "violation_checks": sorted({v["check"] for v in self.violations}),
            "total_steps": self.total_steps,
            "claims": self.n_claims,
            "manifests": self.n_manifests,
            "valid_manifests": self.n_valid_manifests,
            "restores": self.restores,
            "stale_polls": self.stale_polls,
            "polls_ok": self.polls_ok,
            "polls_failed": self.polls_failed,
            "commits_ok": self.commits_ok,
            "commits_failed": self.commits_failed,
            "messages_per_step": round(self.messages_per_step, 3),
            "chief_deaths": len(self.chief_deaths),
            "steps_lost": [d["steps_lost"] for d in self.chief_deaths],
            "chief_recovery": [round(d["recovery_time"], 3)
                               if d["recovery_time"] is not None else None
                               for d in self.chief_deaths],
            "leader_recovery": [round(t, 3) for t in self.leader_recoveries],
            "max_commit_gap": round(self.max_commit_gap, 3),
            "stragglers_flagged": sorted(
                w for w, slow in self.straggler_flags.items() if slow),
        }


#: fault-trace markers that mean "the Raft leader just died"
_LEADER_DEATH_MARKS = ("start crash_restart[leader", "nemesis strikes leader")


def run_fleet(raft: RaftParams, sim: SimParams,
              fleet_params: Optional[FleetParams] = None,
              scenario: Optional[Scenario] = None,
              trace: bool = False) -> FleetResult:
    fp = fleet_params or FleetParams()
    cluster = build_cluster(raft, sim)
    if trace:
        # attach before the boot election so the trace starts at the root
        from ..obs import Tracer
        Tracer(cluster.loop)
    cluster.wait_for_leader()
    fleet = Fleet(cluster, fp)
    ctx = None
    if scenario is not None:
        install_fleet = getattr(scenario, "install_fleet", None)
        if install_fleet is not None:
            ctx = install_fleet(cluster, fleet)
        else:
            ctx = scenario.install(cluster)
    msgs0 = cluster.net.messages_sent
    fleet.start()
    loop = cluster.loop
    loop.run_until(fleet.t0 + fp.duration)
    fleet.running = False
    loop.run_until(loop.now + fp.settle)

    entries = extract_fleet_log(cluster)
    violations = check_lineage(entries, fleet.restores)
    ok_commits = sorted((t, s) for t, s, ok in fleet.commit_log if ok)

    def recovery_after(t: float) -> Optional[float]:
        for tc, _ in ok_commits:
            if tc > t:
                return tc - t
        return None

    chief_deaths = []
    for d in fleet.chief_deaths:
        chief_deaths.append(dict(
            d, steps_lost=max(0, d["local_step"] - d["committed_step"]),
            recovery_time=recovery_after(d["t"])))
    leader_recoveries = []
    if ctx is not None:
        for t, event in ctx.trace:
            if any(m in event for m in _LEADER_DEATH_MARKS):
                rec = recovery_after(t)
                if rec is not None:
                    leader_recoveries.append(rec)

    gap = 0.0
    prev_t = fleet.t0
    for tc, _ in ok_commits:
        gap = max(gap, tc - prev_t)
        prev_t = tc

    ws = list(fleet.workers.values())
    # the straggler table as the launcher would read it at run end
    auth = max(cluster.nodes.values(),
               key=lambda n: (n.alive, n.last_applied, -n.id))
    reports = [json.loads(v) for v in auth.data.get(REPORTS_KEY, [])]
    n_claims = sum(1 for rec, _ in entries if rec.get("kind") == "claim")
    n_manifests = sum(1 for rec, _ in entries
                      if rec.get("kind") == "manifest")
    from .lineage import LogView
    view = LogView()
    for rec, _ in entries:
        view.feed_one(rec)
    total = fleet.total_steps
    return FleetResult(
        violations=violations,
        total_steps=total,
        n_claims=n_claims,
        n_manifests=n_manifests,
        n_valid_manifests=len(view.valid),
        restores=len(fleet.restores),
        stale_polls=sum(w.stale_polls for w in ws),
        polls_ok=sum(w.polls_ok for w in ws),
        polls_failed=sum(w.polls_failed for w in ws),
        commits_ok=sum(w.commits_ok for w in ws),
        commits_failed=sum(w.commits_failed for w in ws),
        messages=cluster.net.messages_sent - msgs0,
        messages_per_step=(cluster.net.messages_sent - msgs0) / max(1, total),
        chief_deaths=chief_deaths,
        leader_recoveries=leader_recoveries,
        max_commit_gap=gap,
        straggler_flags=straggler_flags_from(reports),
        restores_detail=fleet.restores,
        trace=(ctx.trace if ctx is not None else []) + fleet.trace,
        events=(cluster.loop.tracer.events
                if cluster.loop.tracer is not None else []),
    )

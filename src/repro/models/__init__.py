from .transformer import (decode_step, forward_train, hidden_states,
                          init_decode_cache, init_params, prefill)

__all__ = ["decode_step", "forward_train", "hidden_states",
           "init_decode_cache", "init_params", "prefill"]

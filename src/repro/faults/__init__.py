"""Deterministic, composable fault injection (the nemesis engine).

Three layers:

* :mod:`repro.faults.base` — the :class:`Fault` protocol, the
  :class:`Window`/:class:`Scenario` scheduler, and :class:`FaultContext`
  (deterministic victim selection + activation trace);
* :mod:`repro.faults.library` — the fault catalogue: asymmetric and
  partial partitions, majority/minority splits, honest and lying clock
  skew/drift, crash-restart with or without disk loss, scheduled membership churn
  (add/learner-promote/remove via ``change_membership``) and the safe
  wipe-then-learner-rejoin path, message delay /
  duplication / reordering / loss, I/O slowdown, and the leader-chasing
  nemesis;
* :mod:`repro.faults.scenarios` — the named scenario registry (safe vs
  beyond-the-fault-model schedules) plus the ``random_scenario`` /
  ``random_membership_scenario`` / ``random_gray_scenario`` fuzzers.

The catalogue spans three failure-model tiers: crash-stop (crashes,
partitions, message chaos, honest clocks), gray (``SlowNode``
degradation, ``FlappingLink`` duty-cycle flaps — nodes alive but
unreliable), and corruption (``CorruptFault`` field-level AppendEntries
mutation, detected-and-dropped when ``RaftParams.entry_checksums``).

Everything runs on the simulated event loop: a (seed, scenario, policy)
triple replays bit-identically. ``benchmarks/fault_matrix.py`` sweeps the
full policy × scenario × seed cube through ``check_linearizability``.
"""

from .base import Fault, FaultContext, Scenario, Window
from .library import (ClockSkew, CorruptFault, CrashRestart, DiskLossRejoin,
                      FlappingLink, IoSlowdown, IsolateLeader, LeaderNemesis,
                      MajorityMinority, MembershipChaos, MessageChaos,
                      OneWayLink, PartialPartition, SlowNode)
from .scenarios import (SCENARIOS, build_scenario, random_gray_scenario,
                        random_membership_scenario, random_scenario,
                        safe_scenario_names, scenario,
                        unsafe_scenario_names)

__all__ = [
    "Fault", "FaultContext", "Scenario", "Window",
    "ClockSkew", "CorruptFault", "CrashRestart", "DiskLossRejoin",
    "FlappingLink", "IoSlowdown",
    "IsolateLeader", "LeaderNemesis", "MajorityMinority", "MembershipChaos",
    "MessageChaos", "OneWayLink", "PartialPartition", "SlowNode",
    "SCENARIOS", "build_scenario", "random_gray_scenario",
    "random_membership_scenario", "random_scenario",
    "safe_scenario_names", "scenario", "unsafe_scenario_names",
]

"""Trace event schema (version 1) and a dependency-free validator.

The schema is expressed twice from one table: :func:`validate_events`
(pure-Python structural validation used by tests and CI) and
:func:`json_schema` (a JSON-Schema document for external tooling).

Reserved keys on every event — stamped by :meth:`Tracer.emit`:
``id`` (int ≥ 1), ``t`` (seconds, float), ``type`` (str), ``node``
(int | null), ``term`` (int | null), ``parent`` (int | null).

Event types and their payload fields:

========== ============================================================
type       payload
========== ============================================================
role       ``role`` ∈ {follower, candidate, leader, down}, ``reason``
term_bump  ``prev`` (the term before the bump; ``term`` is the new one)
election   ``kind`` ∈ {campaign, prevote}
vote       ``candidate``, ``granted``, ``prevote`` (voter-side record)
lease      ``op`` ∈ {acquire, extend, relinquish, gate_blocked};
           acquire/extend/gate_blocked carry ``entry_term`` + ``until``
           (the lease window's true-time serving deadline,
           ``entry.interval.latest + Δ``)
commit     ``index`` (leader commit advancement)
read       ``op`` ∈ {start, done, fail}; ``key``; done/fail carry
           ``stall`` (seconds from start); fail carries ``error``
write      ``op`` ∈ {start, done, fail}; ``key``; fail carries ``error``
barrier    ``op`` ∈ {start, ok, fail} (policy read barriers, e.g. the
           quorum policy's empty-AppendEntries confirmation round)
fault      ``op`` ∈ {start, stop, note}; ``label`` (fault name / note)
fleet      ``op`` ∈ {claim, deposed, manifest, restore, note} with
           op-specific fields (``wid``, ``epoch``, ``step``, ``ok``,
           ``kind``, ``label``)
========== ============================================================
"""

from __future__ import annotations

import json
from typing import Optional

SCHEMA_NAME = "leaseguard-trace"
SCHEMA_VERSION = 1

_NUM = (int, float)

#: type -> required payload fields -> allowed python types
EVENT_TYPES: dict = {
    "role": {"role": (str,), "reason": (str,)},
    "term_bump": {"prev": (int,)},
    "election": {"kind": (str,)},
    "vote": {"candidate": (int,), "granted": (bool,), "prevote": (bool,)},
    "lease": {"op": (str,)},
    "commit": {"index": (int,)},
    "read": {"op": (str,), "key": (str,)},
    "write": {"op": (str,), "key": (str,)},
    "barrier": {"op": (str,)},
    "fault": {"op": (str,), "label": (str,)},
    "fleet": {"op": (str,)},
}

#: (type, op) -> extra required fields
_OP_FIELDS: dict = {
    ("lease", "acquire"): {"entry_term": (int,), "until": _NUM},
    ("lease", "extend"): {"entry_term": (int,), "until": _NUM},
    ("lease", "gate_blocked"): {"entry_term": (int,), "until": _NUM},
    ("read", "done"): {"stall": _NUM},
    ("read", "fail"): {"stall": _NUM, "error": (str,)},
    ("write", "fail"): {"error": (str,)},
    ("fleet", "claim"): {"wid": (str,), "epoch": (int,)},
    ("fleet", "deposed"): {"wid": (str,)},
    ("fleet", "manifest"): {"step": (int,), "ok": (bool,)},
    ("fleet", "restore"): {"wid": (str,), "kind": (str,)},
    ("fleet", "note"): {"label": (str,)},
}

_OPS: dict = {
    "role": None,  # validated via the "role" field instead
    "lease": {"acquire", "extend", "relinquish", "gate_blocked"},
    "read": {"start", "done", "fail"},
    "write": {"start", "done", "fail"},
    "barrier": {"start", "ok", "fail"},
    "fault": {"start", "stop", "note"},
    "fleet": {"claim", "deposed", "manifest", "restore", "note"},
}

_ROLES = {"follower", "candidate", "leader", "down"}


def header(**meta) -> dict:
    """The first line of every JSONL trace file."""
    h = {"schema": SCHEMA_NAME, "version": SCHEMA_VERSION}
    h.update(meta)
    return h


def _check(e: dict, key: str, types, problems: list, where: str) -> bool:
    if key not in e:
        problems.append(f"{where}: missing field {key!r}")
        return False
    v = e[key]
    # bool is an int subclass; require exact intent
    if bool in types:
        ok = isinstance(v, bool)
    else:
        ok = isinstance(v, types) and not isinstance(v, bool)
    if not ok:
        problems.append(f"{where}: field {key!r} has type "
                        f"{type(v).__name__}, wanted {types}")
        return False
    return True


def validate_event(e: dict, where: str = "event") -> list[str]:
    problems: list[str] = []
    if not isinstance(e, dict):
        return [f"{where}: not an object"]
    _check(e, "id", (int,), problems, where)
    _check(e, "t", _NUM, problems, where)
    for key in ("node", "term", "parent"):
        if key not in e:
            problems.append(f"{where}: missing field {key!r}")
        elif e[key] is not None and (not isinstance(e[key], int)
                                     or isinstance(e[key], bool)):
            problems.append(f"{where}: field {key!r} must be int or null")
    if not _check(e, "type", (str,), problems, where):
        return problems
    etype = e["type"]
    spec = EVENT_TYPES.get(etype)
    if spec is None:
        problems.append(f"{where}: unknown event type {etype!r}")
        return problems
    for key, types in spec.items():
        _check(e, key, types, problems, where)
    if etype == "role" and e.get("role") not in _ROLES:
        problems.append(f"{where}: bad role {e.get('role')!r}")
    ops = _OPS.get(etype)
    if ops and "op" in e:
        op = e["op"]
        if op not in ops:
            problems.append(f"{where}: bad {etype} op {op!r}")
        for key, types in _OP_FIELDS.get((etype, op), {}).items():
            _check(e, key, types, problems, where)
    return problems


def validate_events(events: list, max_problems: int = 50) -> list[str]:
    """Structural validation plus cross-event invariants (ids strictly
    increasing, sim time monotone, parents refer to earlier events)."""
    problems: list[str] = []
    last_id, last_t = 0, float("-inf")
    seen: set = set()
    for i, e in enumerate(events):
        where = f"event[{i}]"
        problems.extend(validate_event(e, where))
        if isinstance(e, dict):
            eid, t, parent = e.get("id"), e.get("t"), e.get("parent")
            if isinstance(eid, int):
                if eid <= last_id:
                    problems.append(f"{where}: id {eid} not increasing")
                last_id = eid
                seen.add(eid)
            if isinstance(t, _NUM) and not isinstance(t, bool):
                if t < last_t:
                    problems.append(f"{where}: time went backwards")
                last_t = t
            if parent is not None and parent not in seen:
                problems.append(f"{where}: parent {parent} not an "
                                f"earlier event id")
        if len(problems) >= max_problems:
            problems.append("... (truncated)")
            break
    return problems


def validate_jsonl(path) -> list[str]:
    """Validate a JSONL trace file: header line + every event line."""
    problems: list[str] = []
    events: list[dict] = []
    with open(path, "r", encoding="utf-8") as fh:
        first = fh.readline()
        try:
            h = json.loads(first)
        except ValueError:
            return [f"{path}: header line is not JSON"]
        if not isinstance(h, dict) or h.get("schema") != SCHEMA_NAME:
            problems.append(f"{path}: bad header schema "
                            f"{h.get('schema') if isinstance(h, dict) else h!r}")
        elif h.get("version") != SCHEMA_VERSION:
            problems.append(f"{path}: unsupported version {h.get('version')}")
        for lineno, line in enumerate(fh, start=2):
            line = line.strip()
            if not line:
                continue
            try:
                events.append(json.loads(line))
            except ValueError:
                problems.append(f"{path}:{lineno}: not JSON")
    problems.extend(validate_events(events))
    return problems


def json_schema() -> dict:
    """A JSON-Schema (draft-07) document for one trace event — generated
    from the same table the validator uses."""
    def jt(types) -> list:
        out = []
        for t in types:
            out.append({int: "integer", float: "number", str: "string",
                        bool: "boolean"}[t])
        if "number" in out and "integer" in out:
            out.remove("integer")
        return out

    variants = []
    for etype, spec in sorted(EVENT_TYPES.items()):
        props = {k: {"type": jt(v)} for k, v in spec.items()}
        props["type"] = {"const": etype}
        variants.append({"properties": props,
                         "required": ["type"] + sorted(spec)})
    return {
        "$schema": "http://json-schema.org/draft-07/schema#",
        "title": f"{SCHEMA_NAME} event (version {SCHEMA_VERSION})",
        "type": "object",
        "properties": {
            "id": {"type": "integer", "minimum": 1},
            "t": {"type": "number"},
            "type": {"enum": sorted(EVENT_TYPES)},
            "node": {"type": ["integer", "null"]},
            "term": {"type": ["integer", "null"]},
            "parent": {"type": ["integer", "null"]},
        },
        "required": ["id", "t", "type", "node", "term", "parent"],
        "anyOf": variants,
    }


def first_problem(events: list) -> Optional[str]:
    problems = validate_events(events)
    return problems[0] if problems else None

"""Single-node membership changes (paper §4.4): overlapping majorities
preserve the Raft guarantees LeaseGuard relies on, so reconfiguration
composes with leases. Elastic scaling for the coordinator."""

import pytest

from repro.core import RaftParams, SimParams, build_cluster
from repro.core.raft import CONFIG, NOOP, AppendEntries, LogEntry


def make(**kw):
    raft = RaftParams(lease_duration=2.0, election_timeout=0.5, **kw)
    return build_cluster(raft, SimParams()), raft


def settle(c, dt):
    c.loop.run_until(c.loop.now + dt)


def run(c, coro):
    return c.loop.run_until_complete(c.loop.create_task(coro))


def test_add_node_replicates_and_votes():
    c, raft = make()
    ldr = c.wait_for_leader()
    assert run(c, ldr.client_write("x", 1)).ok
    new = c.spawn_node(3, raft)
    res = run(c, ldr.change_membership({0, 1, 2, 3}))
    assert res.ok
    settle(c, 1.0)
    assert new.config == {0, 1, 2, 3}
    assert new.data.get("x") == [1]          # caught up from the log
    assert ldr.majority() == 3               # |{0,1,2,3}| // 2 + 1
    # the new node counts: with two original followers down, a majority
    # {leader, new} + one more is needed -> crash ONE follower, still live
    followers = [n for n in c.nodes.values()
                 if n is not ldr and n is not new]
    followers[0].crash()
    assert run(c, ldr.client_write("x", 2)).ok
    settle(c, 0.5)
    assert new.data.get("x") == [1, 2]


def test_remove_node_shrinks_majority():
    c, raft = make(n_nodes=5)
    ldr = c.wait_for_leader()
    victim = next(n for n in c.nodes.values() if n is not ldr)
    res = run(c, ldr.change_membership(set(ldr.config) - {victim.id}))
    assert res.ok
    settle(c, 0.3)
    assert ldr.majority() == 3               # 4 nodes -> majority 3
    victim.crash()                            # removed node dying is a no-op
    others = [n for n in c.nodes.values()
              if n.alive and n is not ldr and n.id in ldr.config]
    others[0].crash()                         # one real failure tolerated
    assert run(c, ldr.client_write("y", 1)).ok


def test_reconfig_rules_enforced():
    c, raft = make()
    ldr = c.wait_for_leader()
    # multi-node change rejected
    res = run(c, ldr.change_membership({0, 1, 2, 3, 4}))
    assert not res.ok and res.error == "only_single_node_changes"
    # removing the leader rejected
    res = run(c, ldr.change_membership(set(ldr.config) - {ldr.id}))
    assert not res.ok and res.error == "cannot_remove_leader"
    # follower can't reconfigure
    f = next(n for n in c.nodes.values() if n is not ldr)
    res = run(c, f.change_membership({0, 1}))
    assert not res.ok and res.error == "not_leader"


def test_lease_reads_work_through_reconfig():
    """The CONFIG entry is an ordinary lease-extending log entry:
    zero-roundtrip reads keep working across the change."""
    c, raft = make()
    ldr = c.wait_for_leader()
    assert run(c, ldr.client_write("k", 1)).ok
    c.spawn_node(3, raft)
    assert run(c, ldr.change_membership({0, 1, 2, 3})).ok
    before = c.net.messages_sent
    res = run(c, ldr.client_read("k"))
    assert res.ok and res.value == [1]
    assert c.net.messages_sent == before     # still zero roundtrips


def test_truncated_config_reverts_to_seed_membership():
    """Regression: conflict truncation can delete EVERY config entry from
    a follower's log (an uncommitted CONFIG from a deposed leader). The
    follower must fall back to its seed config — keeping the truncated
    membership would count majorities against a config no surviving log
    supports."""
    c, raft = make()
    ldr = c.wait_for_leader()
    assert run(c, ldr.client_write("x", 1)).ok
    f = next(n for n in c.nodes.values() if n is not ldr)
    settle(c, 0.3)
    base = f.last_log_index
    # a deposed leader replicated an uncommitted CONFIG to this follower
    # only, then vanished
    f.log.append(LogEntry(f.term, CONFIG, [0, 1, 2, 3],
                          f.log[base].interval))
    f._refresh_config()
    assert f.config == {0, 1, 2, 3}          # newest appended config governs
    # the real next leader's conflicting suffix truncates it away
    reply = f._handle_append(ldr.id, AppendEntries(
        f.term + 1, ldr.id, base, f.log[base].term,
        [LogEntry(f.term + 1, NOOP, None, f.log[base].interval)],
        ldr.commit_index))
    assert reply.success
    assert not any(e.key == CONFIG for e in f.log)
    assert f.config == {0, 1, 2}             # seed config restored
    assert f.majority() == 2


@pytest.mark.parametrize("backoff", [False, True],
                         ids=["plain", "replication_backoff"])
def test_removed_peer_replication_state_pruned(backoff):
    """Regression: removing a member must prune the leader's next/match
    bookkeeping, or stale match_index entries linger across
    reconfigurations (and their heartbeat loops leak). With adaptive
    backoff on, a retry timer parked for the removed peer must be
    cancelled and reaped too — not left to fire into ``next_index`` for
    a ghost peer."""
    c, raft = make(n_nodes=5, replication_backoff=backoff,
                   backoff_base=0.05, backoff_max=0.4)
    ldr = c.wait_for_leader()
    victim = next(n for n in c.nodes.values() if n is not ldr)
    assert victim.id in ldr.next_index and victim.id in ldr.match_index
    if backoff:
        # a dead peer drives the retry loop into parked exponential
        # backoff; step until the leader is mid-park for the victim
        victim.crash()
        deadline = c.loop.now + 5.0
        while victim.id not in ldr._backoff_sleep and c.loop.now < deadline:
            c.loop._step()
        assert victim.id in ldr._backoff_sleep
        assert ldr._backoff_fails.get(victim.id, 0) >= 1
    assert run(c, ldr.change_membership(set(ldr.config) - {victim.id})).ok
    assert victim.id not in ldr.next_index
    assert victim.id not in ldr.match_index
    # the parked timer was woken and reaped synchronously with the prune,
    # and the woken retry task must not re-park for the ghost peer
    assert victim.id not in ldr._backoff_fails
    assert victim.id not in ldr._backoff_sleep
    if victim.alive:
        victim.crash()   # decommission: a removed zombie would campaign
    settle(c, 1.0)
    assert victim.id not in ldr._backoff_sleep
    # bookkeeping tracks exactly the replication set after further churn
    new = c.spawn_node(5, raft, learner=True)
    assert run(c, ldr.change_membership(
        set(ldr.config), learners=set(ldr.learners) | {5})).ok
    settle(c, 1.0)
    assert 5 in ldr.config                   # auto-promoted
    assert set(ldr.next_index) == {p for p in ldr.config if p != ldr.id}
    assert set(ldr.match_index) == set(ldr.next_index)
    assert new.data == ldr.data


def test_reconfig_survives_leader_failover():
    """Leader Completeness carries the CONFIG entry to the next leader."""
    c, raft = make()
    ldr = c.wait_for_leader()
    c.spawn_node(3, raft)
    assert run(c, ldr.change_membership({0, 1, 2, 3})).ok
    settle(c, 0.5)
    ldr.crash()
    settle(c, 3.5)                            # election + lease expiry
    new = next(n for n in c.nodes.values() if n.is_leader())
    assert new.config == {0, 1, 2, 3}
    assert run(c, new.client_write("z", 9)).ok

"""Pallas TPU kernel for the RWKV6 (Finch) WKV recurrence.

    y_t = r_t · (S_{t-1} + (u ⊙ k_t) v_tᵀ)
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ

TPU adaptation: the recurrence is inherently sequential in t, so the
kernel processes the sequence in CHUNKS with the (hd × hd) state matrix
resident in VMEM scratch across the chunk-grid dimension — per-token HBM
round-trips of the state (the naive lowering) are eliminated; HBM traffic
is r/k/v/w in + y out, once. Inside a chunk, a fori_loop runs the
per-token update entirely in VMEM/VREGs. Grid = (batch·heads, n_chunks),
chunk dim minormost so scratch persists across chunks of one head.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, state_scr, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    u = u_ref[0]                                           # (hd,)

    def step(t, state):
        r = r_ref[0, t, :]                                 # (hd,)
        k = k_ref[0, t, :]
        v = v_ref[0, t, :]
        w = w_ref[0, t, :]
        kv = k[:, None] * v[None, :]                       # (hd, hd)
        y = jnp.sum(r[:, None] * (state + u[:, None] * kv), axis=0)
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        return w[:, None] * state + kv

    state = jax.lax.fori_loop(0, chunk, step, state_scr[...])
    state_scr[...] = state


def wkv6_chunked(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                 u: jax.Array, *, chunk: int = 64,
                 interpret: bool = False) -> jax.Array:
    """r,k,v,w: (BH, S, hd) fp32; u: (BH, hd). Returns y (BH, S, hd)."""
    bh, s, hd = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk

    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    seq_spec = pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0))
    return pl.pallas_call(
        kernel,
        grid=(bh, n_chunks),
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, hd), lambda b, c: (b, 0))],
        out_specs=seq_spec,
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)

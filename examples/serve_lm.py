"""Batched serving with leased metadata reads.

Starts a coordinator, commits a model manifest (as training would), then
serves batched generation requests. The engine discovers "which model
version to serve" with a LeaseGuard zero-roundtrip read — the poll every
serving replica does continuously in production.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import jax
import jax.numpy as jnp

from repro.coord.registry import ClusterRegistry
from repro.launch.train import PRESETS
from repro.models import init_params
from repro.serve.engine import Engine, ServeConfig


def main() -> None:
    cfg = PRESETS["tiny"]
    registry = ClusterRegistry()
    registry.commit_checkpoint({"step": 1234, "path": "(in-memory demo)",
                                "sha256": "f" * 64, "n_arrays": 0,
                                "extra": {"arch": cfg.name}})

    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params, ServeConfig(max_new_tokens=12),
                    registry=registry)
    print(f"serving model version: step {engine.model_version['step']} "
          f"(read with zero network roundtrips: "
          f"{registry.coord.stats()['read_messages']} messages for "
          f"{registry.coord.stats()['reads']} reads)")

    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                 cfg.vocab_size)
    out = engine.generate(prompts)
    print(f"generated batch: shape {out.shape}")
    for i, row in enumerate(out):
        print(f"  request {i}: {row.tolist()}")

    # failover drill: coordinator leader dies; the next version poll
    # still succeeds (inherited lease on the new leader)
    registry.coord.crash_leader()
    v = registry.latest_checkpoint()
    print(f"after coordinator failover, version poll still serves: "
          f"step {v['step']}")


if __name__ == "__main__":
    main()

"""Failure forensics: reconstruct *why* a read stalled, failed, or went
stale from a recorded trace's causal chain.

CLI::

    python -m repro.obs.explain TRACE.jsonl [TRACE2.jsonl ...]
    python -m repro.obs.explain traces/            # every *.jsonl inside
    options:
      --validate      validate against the trace schema (exit 1 on error)
      --probe         run the at-most-one-lease-holder probe (exit 1 on
                      violation)
      --failures N    explain up to N failed/stalled reads (default 5)
      --stale N       explain up to N suspected stale reads (default 3)
      --json          machine-readable output

The same analysis feeds :func:`trace_digest`, the compact JSON blob the
benchmark matrices embed in flagged artifact rows — so a violation in
``BENCH_fault_matrix.json`` names the causal election/partition inline.

Causal reconstruction works two ways at once:

* **parent chain**: every read/write/lease event parents to the
  emitting node's role-transition context, and role events chain
  backwards — walking ``parent`` links from a failed read reaches the
  election (or crash) that put the node in the state that refused it;
* **time-window joins**: fault activation windows (``fault`` events)
  and elections are matched to the moment of the failure, naming the
  partition/crash that was active when it happened.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Optional

from .metrics import derive_headline_series
from .probes import at_most_one_lease_holder
from .schema import validate_jsonl


# ----------------------------------------------------------- causal helpers
def index_by_id(events: list) -> dict:
    return {e["id"]: e for e in events}


def causal_chain(by_id: dict, event: dict, max_depth: int = 32) -> list:
    """The event plus its ancestors, root first."""
    chain = [event]
    seen = {event["id"]}
    cur = event
    while len(chain) < max_depth:
        pid = cur.get("parent")
        if pid is None or pid in seen:
            break
        cur = by_id.get(pid)
        if cur is None:
            break
        seen.add(cur["id"])
        chain.append(cur)
    chain.reverse()
    return chain


def active_faults(events: list, t: float) -> list[str]:
    """Fault labels whose [start, stop) window contains t (no stop seen =
    active to the end of the trace)."""
    open_at: dict[str, float] = {}
    active: set[str] = set()
    for e in events:
        if e["type"] != "fault" or e["t"] > t:
            continue
        if e["op"] == "start":
            open_at[e["label"]] = e["t"]
            active.add(e["label"])
        elif e["op"] == "stop":
            active.discard(e["label"])
    return sorted(active)


def election_of_term(events: list, term: int) -> Optional[dict]:
    """The role=leader event that won ``term`` (None if never won)."""
    for e in events:
        if e["type"] == "role" and e["role"] == "leader" \
                and e["term"] == term:
            return e
    return None


def _fmt_cause(events: list, by_id: dict, ev: dict) -> str:
    """One-line causal narrative for a read event (fail or slow done)."""
    node, t = ev["node"], ev["t"]
    chain = causal_chain(by_id, ev)
    role_ev = next((c for c in reversed(chain) if c["type"] == "role"), None)
    bits = []
    if ev["op"] == "fail":
        bits.append(f"read {ev['key']!r} on node {node} failed "
                    f"({ev['error']}) at t={t:.3f}")
    else:
        bits.append(f"read {ev['key']!r} on node {node} at t={t:.3f} "
                    f"(stall {ev.get('stall', 0) * 1e3:.1f} ms)")
    if role_ev is not None:
        bits.append(f"node {node} was {role_ev['role']} since "
                    f"t={role_ev['t']:.3f} ({role_ev['reason']}, "
                    f"term {role_ev['term']})")
    # which leadership superseded this node's view?
    max_term = max((e["term"] for e in events
                    if e["t"] <= t and e["term"] is not None), default=None)
    if max_term is not None and ev["term"] is not None \
            and max_term > ev["term"]:
        win = election_of_term(events, max_term)
        if win is not None:
            bits.append(f"caused by the term-{max_term} election won by "
                        f"node {win['node']} at t={win['t']:.3f} while "
                        f"node {node} still believed term {ev['term']}")
        else:
            bits.append(f"term had moved on to {max_term} without a "
                        f"winner yet")
    faults = active_faults(events, t)
    if faults:
        bits.append("active fault(s): " + ", ".join(faults))
    return "; ".join(bits)


def failed_reads(events: list) -> list:
    return [e for e in events if e["type"] == "read" and e["op"] == "fail"]


def stalled_reads(events: list, min_stall: float = 0.01) -> list:
    return sorted((e for e in events if e["type"] == "read"
                   and e["op"] == "done" and e["stall"] >= min_stall),
                  key=lambda e: -e["stall"])


def stale_read_suspects(events: list) -> list:
    """Reads *served* by a node whose term lagged the cluster maximum at
    serve time — the deposed-leader / lagging-replica signature of the
    inconsistent policy's stale reads. Over-approximate on purpose: a
    suspect is somewhere a stale read COULD have been served; the
    linearizability checker says whether one actually was."""
    suspects = []
    max_term = 0
    for e in events:
        if e["term"] is not None and e["term"] > max_term:
            max_term = e["term"]
        if e["type"] == "read" and e["op"] == "done" \
                and e["term"] is not None and e["term"] < max_term:
            suspects.append(e)
    return suspects


def explain_reads(events: list, n_failures: int = 5,
                  n_stale: int = 3) -> dict:
    by_id = index_by_id(events)
    fails = failed_reads(events)
    stale = stale_read_suspects(events)
    return {
        "failed_reads": len(fails),
        "stale_suspects": len(stale),
        "failure_causes": [_fmt_cause(events, by_id, e)
                           for e in fails[:n_failures]],
        "stale_causes": [_fmt_cause(events, by_id, e)
                         for e in stale[:n_stale]],
        "slowest_reads": [_fmt_cause(events, by_id, e)
                          for e in stalled_reads(events)[:3]],
    }


# ------------------------------------------------------------------ digest
def trace_digest(events: list, t0: float, t1: float,
                 max_items: int = 6) -> dict:
    """The compact forensic summary flagged matrix rows embed: elections,
    fault windows, lease-probe verdict, and up-to-three causal narratives
    for suspect stale / failed reads. Deterministic and small (~1 KB)."""
    by_id = index_by_id(events)
    elections = [{"t": round(e["t"], 6), "node": e["node"],
                  "term": e["term"]}
                 for e in events
                 if e["type"] == "role" and e["role"] == "leader"]
    faults = []
    open_at: dict[str, float] = {}
    for e in events:
        if e["type"] != "fault":
            continue
        if e["op"] == "start":
            open_at[e["label"]] = e["t"]
        elif e["op"] == "stop" and e["label"] in open_at:
            faults.append({"fault": e["label"],
                           "t0": round(open_at.pop(e["label"]), 6),
                           "t1": round(e["t"], 6)})
    for label, t in sorted(open_at.items()):
        faults.append({"fault": label, "t0": round(t, 6), "t1": None})
    probe = at_most_one_lease_holder(events)
    series = derive_headline_series(events, t0, t1)
    stale = stale_read_suspects(events)
    fails = failed_reads(events)
    return {
        "schema": 1,
        "events": len(events),
        "elections": elections[:max_items],
        "n_elections": len(elections),
        "faults": faults[:max_items],
        "lease_probe_violations": len(probe),
        "leader_uptime": round(series["leader_uptime_fraction"], 4),
        "lease_coverage": round(series["lease_coverage"], 4),
        "failed_reads": len(fails),
        "stale_suspects": len(stale),
        "causes": ([_fmt_cause(events, by_id, e) for e in stale[:3]]
                   or [_fmt_cause(events, by_id, e) for e in fails[:3]]),
    }


# --------------------------------------------------------------------- CLI
def _collect_paths(args: list[str]) -> list[Path]:
    paths: list[Path] = []
    for a in args:
        p = Path(a)
        if p.is_dir():
            paths.extend(sorted(p.glob("*.jsonl")))
        else:
            paths.append(p)
    return paths


def explain_file(path: Path, validate: bool = False, probe: bool = False,
                 n_failures: int = 5, n_stale: int = 3) -> dict:
    from .export import read_jsonl
    out: dict = {"trace": str(path)}
    if validate:
        problems = validate_jsonl(path)
        out["schema_problems"] = problems
    head, events = read_jsonl(path)
    out["header"] = head
    t0 = events[0]["t"] if events else 0.0
    t1 = events[-1]["t"] if events else 0.0
    out["series"] = derive_headline_series(events, t0, t1)
    out["reads"] = explain_reads(events, n_failures, n_stale)
    if probe:
        out["lease_probe"] = at_most_one_lease_holder(events)
    return out


def _print_human(r: dict) -> None:
    print(f"== {r['trace']}")
    head = r.get("header", {})
    meta = {k: v for k, v in head.items() if k not in ("schema", "version")}
    if meta:
        print(f"   run: {meta}")
    if "schema_problems" in r:
        ok = not r["schema_problems"]
        print(f"   schema: {'OK' if ok else 'INVALID'}")
        for p in r["schema_problems"][:10]:
            print(f"     ! {p}")
    s = r["series"]
    spans = s["leader_timeline"]
    print(f"   leaderships: {len(spans)}  "
          f"uptime {s['leader_uptime_fraction']:.1%}  "
          f"lease coverage {s['lease_coverage']:.1%}")
    for sp in spans[:8]:
        print(f"     node {sp['node']} term {sp['term']}: "
              f"t={sp['t0']:.3f} -> {sp['t1']:.3f}")
    efc = s["election_to_first_commit"]
    if efc:
        lat = ", ".join(f"t{x['term']}: {x['latency'] * 1e3:.0f}ms"
                        for x in efc[:6])
        print(f"   election -> first commit: {lat}")
    det = [d for d in s["fault_detection"] if d["lag"] is not None]
    for d in det[:6]:
        print(f"   fault {d['fault']} at t={d['t']:.3f} detected "
              f"+{d['lag'] * 1e3:.0f}ms via {d['via']}")
    rd = r["reads"]
    print(f"   reads: {rd['failed_reads']} failed, "
          f"{rd['stale_suspects']} stale suspects")
    for line in rd["failure_causes"]:
        print(f"     fail: {line}")
    for line in rd["stale_causes"]:
        print(f"     stale: {line}")
    for line in rd["slowest_reads"]:
        print(f"     slow: {line}")
    if "lease_probe" in r:
        v = r["lease_probe"]
        print(f"   lease probe: "
              f"{'OK (at most one holder)' if not v else 'VIOLATED'}")
        for x in v[:5]:
            print(f"     ! {x['detail']}")


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.explain",
        description="Reconstruct why reads stalled/failed from a trace.")
    ap.add_argument("paths", nargs="+",
                    help="trace .jsonl files or directories of them")
    ap.add_argument("--validate", action="store_true",
                    help="validate against the trace schema")
    ap.add_argument("--probe", action="store_true",
                    help="run the at-most-one-lease-holder probe")
    ap.add_argument("--failures", type=int, default=5, metavar="N")
    ap.add_argument("--stale", type=int, default=3, metavar="N")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)

    paths = _collect_paths(args.paths)
    if not paths:
        print("no trace files found", file=sys.stderr)
        return 2
    rc = 0
    results = []
    for path in paths:
        r = explain_file(path, validate=args.validate, probe=args.probe,
                         n_failures=args.failures, n_stale=args.stale)
        results.append(r)
        if r.get("schema_problems"):
            rc = 1
        if r.get("lease_probe"):
            rc = 1
    if args.json:
        json.dump(results, sys.stdout, indent=1, default=str)
        print()
    else:
        for r in results:
            _print_human(r)
    return rc


if __name__ == "__main__":
    raise SystemExit(main())

"""arctic-480b — Snowflake Arctic: 128 experts top-2 + parallel dense
residual FFN. [hf:Snowflake/snowflake-arctic-base; hf]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    n_experts=128,
    experts_per_token=2,
    moe_dense_residual=True,
    grad_accum=8,             # activation-memory bound at 1M tokens/step
    optimizer="adafactor",    # Adam states for 480B params exceed v5e HBM
    source="hf:Snowflake/snowflake-arctic-base",
)

"""Unit tests for the training substrate: optimizers (incl. int8-EF
gradient compression), data pipeline determinism, sharding rules, and
the loop-aware roofline analyzer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.train.data import DataConfig, DataIterator, synth_batch
from repro.train.optimizer import (OptConfig, apply_updates,
                                   clip_by_global_norm, init_opt_state,
                                   lr_schedule, quantize_int8)
from repro.configs.base import ShapeConfig


# ------------------------------------------------------------- optimizer
def quad_loss(p):
    return jnp.sum((p["w"] - 3.0) ** 2) + jnp.sum((p["b"] + 1.0) ** 2)


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_reduces_quadratic(name):
    cfg = OptConfig(name=name, lr=0.1, warmup_steps=1, total_steps=200,
                    weight_decay=0.0)
    params = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
    state = init_opt_state(params, cfg)
    loss0 = float(quad_loss(params))
    for step in range(60):
        grads = jax.grad(quad_loss)(params)
        params, state, _ = apply_updates(grads, state, params, cfg, step)
    assert float(quad_loss(params)) < 0.05 * loss0


def test_int8_ef_compression_converges():
    """Error feedback: quantization noise must not prevent convergence."""
    cfg = OptConfig(name="adamw", lr=0.1, warmup_steps=1, total_steps=200,
                    weight_decay=0.0, compress="int8_ef")
    params = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
    state = init_opt_state(params, cfg)
    assert "ef" in state
    for step in range(80):
        grads = jax.grad(quad_loss)(params)
        params, state, _ = apply_updates(grads, state, params, cfg, step)
    assert float(quad_loss(params)) < 0.5


def test_quantize_int8_bounds_and_scale():
    x = jnp.array([-4.0, 0.0, 2.0, 4.0])
    q, scale = quantize_int8(x)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(q.astype(jnp.float32) * scale),
                               np.asarray(x), atol=float(scale))


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


def test_lr_schedule_warmup_and_decay():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(lr_schedule(cfg, 0)) < float(lr_schedule(cfg, 9))
    assert float(lr_schedule(cfg, 99)) < float(lr_schedule(cfg, 20))


# ------------------------------------------------------------------ data
def test_data_deterministic_and_resumable():
    cfg = get_arch("qwen2.5-3b").reduced()
    shape = ShapeConfig("t", "train", 32, 4)
    it1 = DataIterator(cfg, shape)
    batches = [next(it1) for _ in range(3)]
    it2 = DataIterator.from_state(cfg, shape, {"step": 1, "seed": 0})
    b1 = next(it2)
    np.testing.assert_array_equal(batches[1]["tokens"], b1["tokens"])
    # different steps differ
    assert not np.array_equal(batches[0]["tokens"], batches[1]["tokens"])


def test_data_has_learnable_structure():
    cfg = get_arch("qwen2.5-3b").reduced()
    shape = ShapeConfig("t", "train", 64, 2)
    b = synth_batch(cfg, shape, 0)
    toks = np.concatenate([b["tokens"][:, :1], b["labels"]], axis=1)
    # n-gram period 8: most positions repeat 8 steps later
    same = (toks[:, :-8] == toks[:, 8:]).mean()
    assert same > 0.6


def test_stub_archs_get_embeds():
    cfg = get_arch("pixtral-12b").reduced()
    b = synth_batch(cfg, ShapeConfig("t", "train", 16, 2), 0)
    assert "embeds" in b and b["embeds"].shape == (2, 16, cfg.d_model)
    assert "tokens" not in b


# ------------------------------------------------------------- sharding
def test_param_specs_cover_all_archs():
    import os
    from jax.sharding import PartitionSpec
    if jax.device_count() < 8:
        pytest.skip("needs >= 8 host devices (run via dryrun path)")


def test_roofline_loop_multiplication():
    from repro.roofline import analyze_hlo

    def scanned(w, x):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        x, _ = jax.lax.scan(body, x, w)
        return x

    w = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((16, 64), jnp.float32)
    c8 = analyze_hlo(jax.jit(scanned).lower(w, x).compile().as_text())
    w2 = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
    c4 = analyze_hlo(jax.jit(scanned).lower(w2, x).compile().as_text())
    assert c8.flops == pytest.approx(2 * c4.flops, rel=0.05)
    expected = 8 * 2 * 16 * 64 * 64
    assert c8.flops == pytest.approx(expected, rel=0.05)


def test_roofline_counts_collectives():
    from repro.roofline import RooflineCounts, roofline_terms
    c = RooflineCounts(flops=197e12, hbm_bytes=819e9, link_bytes=25e9)
    t = roofline_terms(c, peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(0.5)
    assert t["dominant"] in ("compute", "memory")


def test_model_flops_moe_counts_active_only():
    moe = get_arch("moonshot-v1-16b-a3b")
    assert moe.active_param_count() < 0.35 * moe.param_count()
    dense = get_arch("qwen3-8b")
    assert dense.active_param_count() == dense.param_count()
    # sanity: param counts in the right ballpark
    assert 6e9 < dense.param_count() < 10e9
    assert 300e9 < get_arch("arctic-480b").param_count() < 600e9


# ------------------------------------------------------------------- moe
def test_grouped_moe_matches_flat_dispatch():
    """The grouped dispatch (§Perf iteration 6, off by default) must be
    numerically equivalent to flat dispatch when capacity is ample."""
    import dataclasses
    from repro.models.moe import apply_moe, init_moe
    from repro.sharding import ctx

    cfg = get_arch("moonshot-v1-16b-a3b").reduced()
    cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    key = jax.random.PRNGKey(0)
    p = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (32, cfg.d_model),
                          jnp.float32) * 0.1
    ctx.set_moe_groups(1)
    flat, aux1 = apply_moe(p, x, cfg)
    ctx.set_moe_groups(4)
    try:
        grouped, aux2 = apply_moe(p, x, cfg)
    finally:
        ctx.set_moe_groups(1)
    np.testing.assert_allclose(np.asarray(flat), np.asarray(grouped),
                               atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-5)

"""End-to-end behaviour of the full system: the LeaseGuard control plane
driving the JAX data plane (the paper's availability story exercised
through the real training/serving stack)."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.coord.registry import ClusterRegistry
from repro.core import RaftParams, ReadMode, SimParams, build_cluster
from repro.core.client import Workload
from repro.launch.train import run_training
from repro.models import init_params
from repro.serve.engine import Engine, ServeConfig

TINY = ArchConfig(
    name="sys-tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, grad_accum=1,
    param_dtype="float32")


def test_full_lifecycle_train_failover_serve():
    """Train -> coordinator failover -> checkpoint -> serve the committed
    version, all against one replicated control plane."""
    reg = ClusterRegistry()
    with tempfile.TemporaryDirectory() as d:
        out = run_training(TINY, ShapeConfig("s", "train", 32, 4), 6, d,
                           ckpt_every=3, registry=reg, failover_at=2,
                           log_every=100)
        assert len(out["losses"]) == 6
        manifest = reg.latest_checkpoint()
        assert manifest is not None and manifest["step"] == 6

        # serving discovers the committed version with a leased read
        params = init_params(jax.random.PRNGKey(0), TINY)
        eng = Engine(TINY, params, ServeConfig(max_new_tokens=3),
                     registry=reg)
        assert eng.model_version["step"] == 6
        toks = eng.generate(jnp.zeros((2, 4), jnp.int32))
        assert toks.shape == (2, 3)

    # leased reads are zero-roundtrip: the only messages during read
    # cranks are background heartbeats around the injected failover
    stats = reg.coord.stats()
    assert stats["reads"] > 0
    assert stats["read_messages"] <= 2, stats


def test_loss_decreases_on_structured_data():
    """The synthetic pipeline is learnable: loss drops over 120 steps
    (~0.02s/step after compile; 40 steps sat within noise of the margin)."""
    reg = ClusterRegistry()
    with tempfile.TemporaryDirectory() as d:
        out = run_training(TINY, ShapeConfig("s", "train", 64, 8), 120, d,
                           ckpt_every=200, registry=reg, log_every=100)
    losses = out["losses"]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05, \
        (losses[:5], losses[-5:])


def test_leaseguard_vs_quorum_message_complexity():
    """System-level restatement of the paper's headline: same workload,
    LeaseGuard sends far fewer messages (no per-read quorum round)."""
    sim = SimParams(sim_duration=1.0, interarrival=1e-3, seed=13,
                    write_fraction=0.2)
    counts = {}
    for mode in (ReadMode.LEASEGUARD, ReadMode.QUORUM):
        raft = RaftParams(read_mode=mode)
        c = build_cluster(raft, sim)
        c.wait_for_leader()
        w = Workload(c.loop, c.nodes, c.directory, c.prng.fork(999), sim)
        base = c.net.messages_sent
        c.loop.create_task(w.run(sim.sim_duration))
        c.loop.run_until(c.loop.now + sim.sim_duration + 0.5)
        counts[mode] = c.net.messages_sent - base
        ok = sum(1 for op in w.history if op.success)
        assert ok > 500
    assert counts[ReadMode.QUORUM] > 2.5 * counts[ReadMode.LEASEGUARD]

"""Attention-free sequence mixers.

* RWKV6 ("Finch"): token-shift + data-dependent per-channel decay, matrix
  WKV state (head_dim × head_dim per head) — O(1) state decode, the reason
  rwkv6-3b runs the long_500k shape.
* Mamba-style selective SSM head for hymba's hybrid layers (parallel
  attention + SSM in the same layer), ssm_state=16.

Both expose a full-sequence path (lax.scan over time — the oracle for the
Pallas chunked kernel) and a single-step decode path over carried state.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.ctx import constrain
from .layers import dense_init, group_norm_heads

DECAY_LORA = 64
DT_RANK = 64
CONV_K = 4
TIME_CHUNK = 256


def chunked_time_scan(step_fn, state0, seq, chunk: int = TIME_CHUNK):
    """scan-over-time in rematerialized chunks: backward keeps only
    chunk-boundary states instead of one residual per token (32 states
    for a 4k+ sequence vs 4096). This mirrors the chunked Pallas kernels
    (kernels/rwkv6.py) and is what makes SSM training memory-feasible."""
    S = jax.tree.leaves(seq)[0].shape[0]
    if S <= chunk or S % chunk != 0:
        return jax.lax.scan(step_fn, state0, seq)
    n = S // chunk
    seq_c = jax.tree.map(lambda a: a.reshape(n, chunk, *a.shape[1:]), seq)

    @partial(jax.checkpoint, prevent_cse=False)
    def chunk_body(state, chunk_seq):
        return jax.lax.scan(step_fn, state, chunk_seq)

    final, ys = jax.lax.scan(chunk_body, state0, seq_c)
    ys = jax.tree.map(lambda a: a.reshape(S, *a.shape[2:]), ys)
    return final, ys


# ============================================================== RWKV6
def init_rwkv_tmix(key: jax.Array, cfg: ArchConfig, dtype) -> dict:
    d = cfg.d_model
    h = d // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    ks = jax.random.split(key, 10)
    return {
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),     # r,k,v,w,g shift mixes
        "w_r": dense_init(ks[0], (d, d), dtype),
        "w_k": dense_init(ks[1], (d, d), dtype),
        "w_v": dense_init(ks[2], (d, d), dtype),
        "w_g": dense_init(ks[3], (d, d), dtype),
        "w_o": dense_init(ks[4], (d, d), dtype),
        "w0": -6.0 * jnp.ones((d,), jnp.float32),      # decay bias
        "w_lora_a": dense_init(ks[5], (d, DECAY_LORA), jnp.float32),
        "w_lora_b": dense_init(ks[6], (DECAY_LORA, d), jnp.float32, 0.1),
        "bonus_u": dense_init(ks[7], (h, hd), jnp.float32),
        "ln_w": jnp.ones((hd,), jnp.float32),
        "ln_b": jnp.zeros((hd,), jnp.float32),
    }


def init_rwkv_cmix(key: jax.Array, cfg: ArchConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu": 0.5 * jnp.ones((2, d), jnp.float32),     # k, r shift mixes
        "w_k": dense_init(ks[0], (d, f), dtype),
        "w_v": dense_init(ks[1], (f, d), dtype),
        "w_r": dense_init(ks[2], (d, d), dtype),
    }


def _shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """Token shift: y_t = x_{t-1}; y_0 = prev. x: (B,S,D), prev: (B,D)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def rwkv_decay(p: dict, xw: jax.Array) -> jax.Array:
    """Data-dependent decay in (0,1): exp(-exp(w0 + lora(x)))."""
    w = p["w0"] + jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"]) @ p["w_lora_b"]
    return jnp.exp(-jnp.exp(w))


def wkv_step(state, rkvw, u):
    """One WKV6 recurrence step.
    state: (B,H,hd,hd) [key-dim i, value-dim j]
    r,k,v,decay: (B,H,hd); u: (H,hd)
    """
    r, k, v, decay = rkvw
    kv = k[..., :, None] * v[..., None, :]               # (B,H,hd,hd)
    y = jnp.einsum("bhi,bhij->bhj", r, state + u[None, :, :, None] * kv)
    new_state = decay[..., :, None] * state + kv
    return new_state, y


def apply_rwkv_tmix(p: dict, x: jax.Array, cfg: ArchConfig,
                    state: dict | None = None) -> tuple[jax.Array, dict]:
    """x: (B,S,D). state: {"shift": (B,D), "wkv": (B,H,hd,hd)} or None."""
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    prev = state["shift"] if state is not None else jnp.zeros((b, d), x.dtype)
    wkv0 = state["wkv"] if state is not None else \
        jnp.zeros((b, h, hd, hd), jnp.float32)

    xx = _shift(x, prev)
    mix = lambda i: x + (xx - x) * p["mu"][i].astype(x.dtype)
    proj = lambda i, w: constrain(mix(i) @ w, "dp", None, "tp")
    r = proj(0, p["w_r"]).reshape(b, s, h, hd)
    k = proj(1, p["w_k"]).reshape(b, s, h, hd)
    v = proj(2, p["w_v"]).reshape(b, s, h, hd)
    g = proj(4, p["w_g"])
    decay = rwkv_decay(p, mix(3)).reshape(b, s, h, hd)   # fp32

    rkvw = (r.astype(jnp.float32).transpose(1, 0, 2, 3),
            k.astype(jnp.float32).transpose(1, 0, 2, 3),
            v.astype(jnp.float32).transpose(1, 0, 2, 3),
            decay.transpose(1, 0, 2, 3))
    # VMEM-resident on the TPU target (kernels/rwkv6.py chunked kernel)
    with jax.named_scope("vmemkernel_wkv6"):
        wkv_final, ys = chunked_time_scan(
            lambda st, rkvw_t: wkv_step(st, rkvw_t, p["bonus_u"]), wkv0, rkvw)
    y = ys.transpose(1, 0, 2, 3)                          # (B,S,H,hd)
    y = group_norm_heads(y, p["ln_w"], p["ln_b"]).reshape(b, s, d)
    out = (y * jax.nn.silu(g).astype(y.dtype)).astype(x.dtype) @ p["w_o"]
    out = constrain(out, "dp", "sp", None)
    new_state = {"shift": x[:, -1, :], "wkv": wkv_final}
    return out, new_state


def apply_rwkv_cmix(p: dict, x: jax.Array, cfg: ArchConfig,
                    state: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    b, s, d = x.shape
    prev = state if state is not None else jnp.zeros((b, d), x.dtype)
    xx = _shift(x, prev)
    mix = lambda i: x + (xx - x) * p["mu"][i].astype(x.dtype)
    k = jnp.square(jax.nn.relu(constrain(mix(0) @ p["w_k"],
                                         "dp", None, "tp")))
    v = constrain(k @ p["w_v"], "dp", "sp", None)
    r = jax.nn.sigmoid(mix(1) @ p["w_r"])
    return (r * v).astype(x.dtype), x[:, -1, :]


# ====================================================== Mamba (hymba)
def init_mamba(key: jax.Array, cfg: ArchConfig, dtype) -> dict:
    d = cfg.d_model
    di = cfg.n_heads * cfg.hd                # SSM heads mirror attn heads
    n = cfg.ssm_state
    ks = jax.random.split(key, 7)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": dense_init(ks[1], (CONV_K, di), dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "dt_a": dense_init(ks[2], (di, DT_RANK), dtype),
        "dt_b": dense_init(ks[3], (DT_RANK, di), dtype),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "w_bc": dense_init(ks[4], (di, 2 * n), dtype),
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], (di, d), dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 conv_state: jax.Array | None) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d. x: (B,S,di); w: (K,di)."""
    bsz, s, di = x.shape
    if conv_state is None:
        conv_state = jnp.zeros((bsz, CONV_K - 1, di), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)        # (B, S+K-1, di)
    out = sum(xp[:, i:i + s, :] * w[i] for i in range(CONV_K)) + b
    return out, xp[:, -(CONV_K - 1):, :]


def apply_mamba(p: dict, x: jax.Array, cfg: ArchConfig,
                state: dict | None = None) -> tuple[jax.Array, dict]:
    """Selective SSM. x: (B,S,D). state: {"conv": (B,K-1,di),
    "h": (B,di,n)}."""
    b, s, d = x.shape
    n = cfg.ssm_state
    xz = constrain(x @ p["in_proj"], "dp", None, None)
    x_in, z = jnp.split(xz, 2, axis=-1)                  # (B,S,di) each
    conv_state = state["conv"] if state is not None else None
    x_c, new_conv = _causal_conv(x_in, p["conv_w"], p["conv_b"], conv_state)
    x_c = jax.nn.silu(x_c)

    dt = jax.nn.softplus(
        (x_c @ p["dt_a"] @ p["dt_b"]).astype(jnp.float32) + p["dt_bias"])
    bc = x_c @ p["w_bc"]
    b_t, c_t = jnp.split(bc.astype(jnp.float32), 2, axis=-1)  # (B,S,n)
    a = -jnp.exp(p["a_log"])                              # (di,n)
    x_f = x_c.astype(jnp.float32)

    h0 = state["h"] if state is not None else jnp.zeros((b, x_in.shape[-1], n),
                                                        jnp.float32)

    def step(h, t):
        dt_t, b_tt, c_tt, x_t = t                        # (B,di),(B,n),(B,n),(B,di)
        da = jnp.exp(dt_t[..., None] * a[None])          # (B,di,n)
        h = da * h + (dt_t * x_t)[..., None] * b_tt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_tt)
        return h, y

    seq = (dt.transpose(1, 0, 2), b_t.transpose(1, 0, 2),
           c_t.transpose(1, 0, 2), x_f.transpose(1, 0, 2))
    with jax.named_scope("vmemkernel_mamba_scan"):
        h_final, ys = chunked_time_scan(step, h0, seq)
    y = ys.transpose(1, 0, 2) + p["d_skip"] * x_f        # (B,S,di)
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    out = constrain(out, "dp", "sp", None)
    return out, {"conv": new_conv, "h": h_final}

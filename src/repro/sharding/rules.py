"""Logical-axis → mesh-axis sharding rules.

Strategy (DESIGN.md §6):
* ``model`` axis: tensor parallelism — attention/MLP projections sharded on
  the flattened head/ffn dim; MoE experts sharded on the expert dim (EP);
  vocab-parallel embedding + LM head.
* ``data`` axis: FSDP — the other weight dim + optimizer states sharded;
  the batch dim of activations.
* ``pod`` axis (multi-pod): pure data parallelism — params replicated
  across pods (no cross-DCI all-gathers in the layer loop), batch sharded
  over (pod, data), gradient all-reduce crosses pods once per step.

Any dim not divisible by its mesh-axis extent falls back to replication
for that dim (e.g. hymba's vocab 32001).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig

# name-keyed rules: (dim_roles...) where each role is one of
#   "tp"   -> model axis
#   "fsdp" -> data axis
#   None   -> replicated
_RULES: dict[str, tuple] = {
    # embeddings (vocab-parallel)
    "embed": ("tp", "fsdp"),
    "lm_head": ("fsdp", "tp"),
    # attention (flat head dims)
    "wq": ("fsdp", "tp"), "wk": ("fsdp", "tp"), "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    "bq": ("tp",), "bk": ("tp",), "bv": ("tp",),
    # dense mlp
    "w_gate": ("fsdp", "tp"), "w_up": ("fsdp", "tp"), "w_down": ("tp", "fsdp"),
    # rwkv time/channel mix
    "w_r": ("fsdp", "tp"), "w_k": ("fsdp", "tp"), "w_v": ("tp", "fsdp"),
    "w_g": ("fsdp", "tp"), "w_o": ("tp", "fsdp"),
    "w_lora_a": (None, None), "w_lora_b": (None, None),
    # mamba
    "in_proj": ("fsdp", "tp"), "out_proj": ("tp", "fsdp"),
    "dt_a": ("fsdp", None), "dt_b": (None, "fsdp"),
    "w_bc": ("fsdp", None), "conv_w": (None, "tp"),
    "a_log": ("tp", None), "bonus_u": (None, None),
    # moe (expert-parallel)
    "router": ("fsdp", None),
}
# MoE expert tensors are rank-3 and share names with dense mlp weights;
# disambiguated by rank below.
_MOE_RULES = {
    "w_gate": ("tp", "fsdp", None),
    "w_up": ("tp", "fsdp", None),
    "w_down": ("tp", None, "fsdp"),
}


def _axis(role: Optional[str], *, dp_axis="data", tp_axis="model"):
    if role == "tp":
        return tp_axis
    if role == "fsdp":
        return dp_axis
    return None


def _spec_for(path_keys: list[str], leaf_shape: tuple, mesh_axes: dict,
              stacked: bool) -> P:
    name = path_keys[-1] if path_keys else ""
    in_moe = "moe" in path_keys and "dense" not in path_keys
    base_rank = len(leaf_shape) - (1 if stacked else 0)
    if in_moe and name in _MOE_RULES and base_rank == 3:
        roles = _MOE_RULES[name]
    else:
        roles = _RULES.get(name)
    if roles is None or len(roles) != base_rank:
        roles = (None,) * base_rank
    axes = [_axis(r) for r in roles]
    # divisibility fallback: replicate dims the mesh doesn't divide
    dims = leaf_shape[1:] if stacked else leaf_shape
    fixed = []
    for d, a in zip(dims, axes):
        if a is not None and d % mesh_axes.get(a, 1) != 0:
            a = None
        fixed.append(a)
    if stacked:
        fixed = [None] + fixed
    return P(*fixed)


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
    return out


def param_specs(params_tree: Any, mesh: Mesh, mode: str = "train") -> Any:
    """PartitionSpec pytree mirroring ``params_tree`` (arrays or
    ShapeDtypeStructs).

    ``mode="serve"``: TP-only — the FSDP ('data') dim is replicated.
    Decode steps would otherwise all-gather every layer's weights per
    generated token (§Perf iteration 5: the dominant decode collective);
    serving replicas keep full TP shards resident instead."""
    mesh_axes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(path, leaf):
        names = _path_names(path)
        stacked = "layers" in names
        spec = _spec_for(names, leaf.shape, mesh_axes, stacked)
        if mode == "serve":
            spec = P(*[None if a in ("data", ("pod", "data"), "pod") else a
                       for a in spec])
        return spec

    return jax.tree_util.tree_map_with_path(one, params_tree)


def opt_specs(opt_tree: Any, params_spec_tree: Any, mesh: Mesh) -> Any:
    """Optimizer-state specs: adam m/v/ef mirror the param spec; adafactor
    row/col drop the corresponding trailing dim."""
    def one(path, leaf):
        names = _path_names(path)
        # strip the leading container key ("m"/"v"/"ef"/"f") and any
        # trailing factored key ("row"/"col"/"v")
        inner = [n for n in names if n not in ("m", "v", "ef", "f", "row", "col")]
        mesh_axes = dict(zip(mesh.axis_names, mesh.devices.shape))
        stacked = "layers" in inner
        tail = names[-1]
        base = _spec_for(inner, leaf.shape, mesh_axes, stacked)
        if tail == "row" or tail == "col":
            # factored stats: recompute spec for the reduced shape by
            # dropping the last (row) / second-to-last (col) dim role
            full_names = inner
            # derive roles for the full param then cut one dim
            # simplest robust fallback: replicate factored stats
            return P(*([None] * leaf.shape.__len__()))
        return base

    return jax.tree_util.tree_map_with_path(one, opt_tree)


def batch_specs(batch_tree: Any, mesh: Mesh) -> Any:
    """Batch dim over all data-parallel axes (pod, data)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_axes = dp if len(dp) > 1 else (dp[0] if dp else None)
    mesh_axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_size = 1
    for a in ("pod", "data"):
        if a in mesh_axes:
            dp_size *= mesh_axes[a]

    def one(leaf):
        if leaf.ndim == 0 or leaf.shape[0] % dp_size != 0:
            return P()
        return P(dp_axes, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(one, batch_tree)


def cache_specs(cache_tree: Any, mesh: Mesh) -> Any:
    """Decode caches: (L, B, ...) — shard B over dp axes when divisible,
    plus one feature dim over 'model': for 5-D KV caches
    (L, B, S, Hkv, hd) prefer the kv-head dim, falling back to the head
    dim (all zoo archs have hd % 16 == 0). A 32k-deep MHA cache
    (musicgen: 3.3 TB global) does not fit per-device memory under
    batch-only sharding."""
    mesh_axes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_axes = dp if len(dp) > 1 else (dp[0] if dp else None)
    dp_size = 1
    for a in dp:
        dp_size *= mesh_axes[a]
    tp = mesh_axes.get("model", 1)

    def one(leaf):
        spec = [None] * leaf.ndim
        if leaf.ndim >= 2 and leaf.shape[1] % dp_size == 0:
            spec[1] = dp_axes
        if leaf.ndim >= 4:
            # try feature dims from the head dim outward: Hkv then hd
            if leaf.ndim >= 5 and leaf.shape[3] % tp == 0:
                spec[3] = "model"
            elif leaf.shape[-1] % tp == 0:
                spec[-1] = "model"
        return P(*spec)

    return jax.tree.map(one, cache_tree)


def state_specs(state_shapes: dict, mesh: Mesh) -> dict:
    """Specs for a full train state {params, opt, step}."""
    pspecs = param_specs(state_shapes["params"], mesh)
    return {
        "params": pspecs,
        "opt": opt_specs(state_shapes["opt"], pspecs, mesh),
        "step": P(),
    }


def to_named(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))

"""Deterministic fallback for the subset of the hypothesis API we use.

When the real ``hypothesis`` package is installed, the property tests
import it directly and this module is unused. When it is absent (the
paper-repro container does not ship it), the tests fall back to this
stub: each ``@given`` test runs a small, fixed set of examples drawn
from a seeded PRNG, so the suite still collects and exercises the
properties deterministically everywhere.
"""

from __future__ import annotations

import functools
import inspect
import random

# Keep this small: several property tests run a full simulated workload
# per example. The fixed seed makes every CI run identical.
_MAX_EXAMPLES = 3
_SEED = 0xC0FFEE


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value=0, max_value=1 << 30) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        items = list(seq)
        return _Strategy(lambda rng: items[rng.randrange(len(items))])


st = strategies


def settings(max_examples=None, deadline=None, **_ignored):
    """Records the requested example budget (capped at _MAX_EXAMPLES)."""
    def deco(fn):
        fn._stub_max_examples = min(max_examples or _MAX_EXAMPLES,
                                    _MAX_EXAMPLES)
        return fn
    return deco


def given(*pos_strats, **kw_strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(fn, "_stub_max_examples", _MAX_EXAMPLES)
            rng = random.Random(_SEED)
            for _ in range(n):
                pos = tuple(s.example(rng) for s in pos_strats)
                drawn = {k: s.example(rng) for k, s in kw_strats.items()}
                fn(*args, *pos, **drawn, **kwargs)
        # all of the test's parameters are supplied by the strategies, so
        # hide them from pytest's fixture resolution
        wrapper.__signature__ = inspect.Signature()
        del wrapper.__wrapped__
        return wrapper
    return deco

"""Loop-aware roofline analysis from compiled HLO text.

``compiled.cost_analysis()`` counts each HLO op ONCE — a ``lax.scan`` body
(our layer loop, grad-accum loop, and SSM time loop) is counted a single
time regardless of trip count, which would understate a 48-layer model by
48x. This module re-derives the three roofline terms from the HLO text
with **while-loop trip multiplication**:

* parse computations and ops (opcode, result shape/dtype, operand refs);
* find ``while`` ops, recover trip counts from the loop-condition's
  comparison constant, and multiply nested body costs;
* FLOPs: 2·M·N·K for every ``dot`` (contraction dims parsed from
  ``dot_dimension_numbers``); convolutions likewise. Elementwise flops are
  ignored (matmul-dominated workloads; the gap shows up in the
  MODEL_FLOPS/HLO_FLOPS ratio instead);
* HBM bytes: every top-level op is an HBM-to-HBM kernel post-fusion, so
  traffic ≈ Σ (operand bytes + result bytes) over non-trivial ops;
* collective bytes: per-device link traffic with a ring model —
  all-reduce 2(g-1)/g·n, all-gather/reduce-scatter (g-1)/g·n_full,
  all-to-all (g-1)/g·n, collective-permute n.

Terms (seconds, per device — the workload is SPMD so per-device = critical
path):
    compute    = flops_per_dev / PEAK_FLOPS_BF16
    memory     = hbm_bytes_per_dev / HBM_BW
    collective = link_bytes_per_dev / ICI_BW
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """'bf16[8,128]{1,0}' -> bytes. Tuples handled by caller."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclass
class Op:
    name: str
    opcode: str
    result_type: str
    line: str


@dataclass
class Computation:
    name: str
    ops: dict[str, Op] = field(default_factory=dict)
    order: list[str] = field(default_factory=list)


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
# result type = everything (lazily) up to the first "opcode(" token; this
# survives tuple types with /*index=N*/ comments.
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s([\w\-]+)\(")


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if line.rstrip().endswith("{") and ("->" in line or line.startswith("ENTRY")):
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.strip().startswith("ENTRY"):
                    comps["__entry__"] = cur
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            op = Op(m.group(1), m.group(3), m.group(2), line)
            cur.ops[op.name] = op
            cur.order.append(op.name)
    return comps


def _called_computations(line: str) -> list[str]:
    out = []
    for key in ("calls=", "to_apply=", "body=", "condition=",
                "true_computation=", "false_computation="):
        for m in re.finditer(re.escape(key) + r"%?([\w\.\-]+)", line):
            out.append(m.group(1))
    return out


def _while_parts(line: str) -> tuple[Optional[str], Optional[str]]:
    body = re.search(r"body=%?([\w\.\-]+)", line)
    cond = re.search(r"condition=%?([\w\.\-]+)", line)
    return (body.group(1) if body else None, cond.group(1) if cond else None)


def _trip_count(comps: dict, cond_name: str) -> int:
    """Heuristic: the largest integer constant in the loop condition."""
    comp = comps.get(cond_name)
    if comp is None:
        return 1
    best = 1
    for op in comp.ops.values():
        if op.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", op.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(op: Op, defs: dict[str, str]) -> float:
    """2*M*N*K from result shape and contracting dims of the lhs."""
    out_elems = _shape_elems(op.result_type)
    m = re.search(r"(?:lhs_contracting_dims|rhs_contracting_dims)=\{([0-9,]*)\}",
                  op.line)
    # operand shapes: resolve the first two %refs after the opcode
    refs = re.findall(r"%([\w\.\-]+)", op.line.split(op.opcode + "(", 1)[-1])
    k = 1
    if refs:
        lhs_type = defs.get(refs[0], "")
        ms = _SHAPE_RE.search(lhs_type)
        mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
        if ms and mc and mc.group(1):
            dims = [int(d) for d in ms.group(2).split(",") if d]
            for ci in mc.group(1).split(","):
                ci = int(ci)
                if ci < len(dims):
                    k *= dims[ci]
        # batch dims are already part of out_elems
    return 2.0 * out_elems * k


_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "copy", "after-all", "iota",
}


def _dus_root(comps: dict, op: "Op") -> Optional[str]:
    """If a fusion's root is a dynamic-(update-)slice, HBM traffic is the
    SLICE, not the full buffer (scan stashes would otherwise count the
    whole (L, ...) stack per layer). Returns the root opcode or None."""
    if op.opcode in ("dynamic-update-slice", "dynamic-slice"):
        return op.opcode
    if op.opcode != "fusion":
        return None
    for sub in _called_computations(op.line):
        comp = comps.get(sub)
        if comp is None or not comp.order:
            continue
        root = comp.ops.get(comp.order[-1])
        if root is not None and root.opcode in ("dynamic-update-slice",
                                                "dynamic-slice"):
            return root.opcode
    return None


def _collective_link_bytes(op: Op, defs: dict[str, str]) -> float:
    nbytes = _shape_bytes(op.result_type)
    g = 1
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.line)
    if m:
        g = int(m.group(2))
    else:
        m = re.search(r"replica_groups=\{\{([^}]*)\}", op.line)
        if m:
            g = len(m.group(1).split(","))
    g = max(g, 1)
    if op.opcode == "all-reduce":
        return 2.0 * (g - 1) / g * nbytes
    if op.opcode == "all-gather":
        return (g - 1) / g * nbytes            # result is the gathered full
    if op.opcode == "reduce-scatter":
        return (g - 1) * nbytes                 # operand = result * g
    if op.opcode == "all-to-all":
        return (g - 1) / g * nbytes
    if op.opcode == "collective-permute":
        return float(nbytes)
    return 0.0


@dataclass
class RooflineCounts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    link_bytes: float = 0.0
    kernel_region_bytes: float = 0.0   # traffic inside vmemkernel_* scopes:
    #   resident in VMEM once the Pallas kernel replaces the XLA reference
    #   (see kernels/); reported separately so both the XLA-reference and
    #   the kernel-adjusted memory terms are visible.
    collective_breakdown: dict = field(default_factory=dict)
    n_collectives: int = 0

    def add(self, other: "RooflineCounts", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.link_bytes += other.link_bytes * mult
        self.kernel_region_bytes += other.kernel_region_bytes * mult
        self.n_collectives += int(other.n_collectives * mult)
        for k, v in other.collective_breakdown.items():
            self.collective_breakdown[k] = \
                self.collective_breakdown.get(k, 0.0) + v * mult


def _mult_map(comps: dict) -> tuple[dict, dict]:
    """(loop multiplier per computation, direct trip count per while-body).

    A computation called from a while body inherits the body's multiplier;
    the body itself gets parent_mult × trips."""
    entry = comps["__entry__"]
    mult: dict[str, float] = {entry.name: 1.0}
    direct: dict[str, int] = {}
    frontier = [entry.name]
    seen: set[str] = set()
    while frontier:
        cname = frontier.pop()
        if cname in seen:
            continue
        seen.add(cname)
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult.get(cname, 1.0)
        for op in comp.ops.values():
            if op.opcode == "while":
                body, cond = _while_parts(op.line)
                trips = _trip_count(comps, cond) if cond else 1
                if body:
                    mult[body] = max(mult.get(body, 0.0), m * trips)
                    direct[body] = trips
                    frontier.append(body)
            else:
                for sub in _called_computations(op.line):
                    mult[sub] = max(mult.get(sub, 0.0), m)
                    if cname in direct:
                        # calls from inside a loop body keep its trip for
                        # the sliced-operand heuristic
                        direct.setdefault(sub, direct[cname])
                    frontier.append(sub)
    return mult, direct


def analyze_hlo(text: str) -> RooflineCounts:
    comps = parse_hlo(text)
    if "__entry__" not in comps:
        raise ValueError("no ENTRY computation found")
    defs: dict[str, str] = {}
    fusion_of: dict[str, str] = {}
    for c in comps.values():
        for op in c.ops.values():
            defs[op.name] = op.result_type
    mult, direct = _mult_map(comps)

    def _lead_dim(type_str: str) -> int:
        m = re.match(r"[a-z0-9]+\[(\d+)", type_str)
        return int(m.group(1)) if m else 1

    total = RooflineCounts()
    counted_fusion_flops: set[tuple[str, str]] = set()
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        m = mult.get(cname)
        if m is None:
            continue  # unreachable computation
        trip_here = direct.get(cname, 0)
        for op in comp.ops.values():
            if op.opcode == "while":
                continue
            if op.opcode in ("dot", "convolution"):
                total.flops += _dot_flops(op, defs) * m
            base = op.opcode.replace("-start", "")
            if base in _COLLECTIVES:
                lb = _collective_link_bytes(op, defs) * m
                total.link_bytes += lb
                total.n_collectives += int(m)
                total.collective_breakdown[base] = \
                    total.collective_breakdown.get(base, 0.0) + lb
            # HBM traffic: only at top level (fusion internals are virtual).
            # Heuristic: a computation reached via calls= from a fusion is
            # internal — detected by name prefix "fused_" / "wrapped_" /
            # region-style names don't matter since we count every
            # computation once with its multiplier; to avoid double count,
            # only ops in NON-fusion-internal computations contribute.
            if comp.name.startswith(("fused_", "wrapped_")):
                continue
            if op.opcode in _SKIP_BYTES_OPS:
                continue
            dus = _dus_root(comps, op)
            rb = _shape_bytes(op.result_type)
            if dus == "dynamic-update-slice":
                traffic = 3.0 * rb / max(1, _lead_dim(op.result_type))
            elif dus == "dynamic-slice":
                traffic = 2.0 * rb
            else:
                ob = 0.0
                tail = op.line.split(op.opcode + "(", 1)[-1].split(")", 1)[0]
                for ref in re.findall(r"%([\w\.\-]+)", tail):
                    t = defs.get(ref, "")
                    b = _shape_bytes(t)
                    # sliced-stack heuristic: inside a trip-T loop body, an
                    # operand stacked with leading dim T is read one slice
                    # per iteration
                    if trip_here > 1 and _lead_dim(t) == trip_here:
                        b = b / trip_here
                    ob += b
                traffic = rb + ob
            if "vmemkernel_" in op.line:
                total.kernel_region_bytes += traffic * m
            else:
                total.hbm_bytes += traffic * m
    return total


def collective_inventory(text: str, top: int = 20) -> list[dict]:
    """Profile tool for §Perf: every collective with its loop-multiplied
    per-device link bytes, sorted by total contribution. The op_name
    metadata says which jax-level op generated it."""
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    defs: dict[str, str] = {}
    for c in comps.values():
        for op in c.ops.values():
            defs[op.name] = op.result_type

    # compute loop multiplier per computation via BFS from entry
    mult: dict[str, float] = {entry.name: 1.0}
    frontier = [entry.name]
    seen = set()
    while frontier:
        cname = frontier.pop()
        if cname in seen:
            continue
        seen.add(cname)
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult.get(cname, 1.0)
        for op in comp.ops.values():
            if op.opcode == "while":
                body, cond = _while_parts(op.line)
                trips = _trip_count(comps, cond) if cond else 1
                if body:
                    mult[body] = max(mult.get(body, 0.0), m * trips)
                    frontier.append(body)
            else:
                for sub in _called_computations(op.line):
                    mult[sub] = max(mult.get(sub, 0.0), m)
                    frontier.append(sub)

    rows = []
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        m = mult.get(cname)
        if m is None:
            continue
        for op in comp.ops.values():
            base = op.opcode.replace("-start", "")
            if base not in _COLLECTIVES:
                continue
            lb = _collective_link_bytes(op, defs)
            meta = re.search(r'op_name="([^"]+)"', op.line)
            rows.append({
                "op": base,
                "shape": op.result_type.split("{")[0][:48],
                "trips": m,
                "link_bytes_total": lb * m,
                "source": (meta.group(1)[-110:] if meta else ""),
            })
    rows.sort(key=lambda r: -r["link_bytes_total"])
    return rows[:top]


def hbm_inventory(text: str, top: int = 20) -> list[dict]:
    """Top HBM-traffic ops (loop-multiplied), for the memory-bound cells."""
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    defs: dict[str, str] = {}
    for c in comps.values():
        for op in c.ops.values():
            defs[op.name] = op.result_type
    mult: dict[str, float] = {entry.name: 1.0}
    frontier = [entry.name]
    seen = set()
    while frontier:
        cname = frontier.pop()
        if cname in seen:
            continue
        seen.add(cname)
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult.get(cname, 1.0)
        for op in comp.ops.values():
            if op.opcode == "while":
                body, cond = _while_parts(op.line)
                trips = _trip_count(comps, cond) if cond else 1
                if body:
                    mult[body] = max(mult.get(body, 0.0), m * trips)
                    frontier.append(body)

    rows = []
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        m = mult.get(cname)
        if m is None:
            continue
        for op in comp.ops.values():
            if op.opcode in _SKIP_BYTES_OPS or op.opcode == "while":
                continue
            dus = _dus_root(comps, op)
            rb = _shape_bytes(op.result_type)
            if dus is not None:
                m_lead = re.match(r"[a-z0-9]+\[(\d+)", op.result_type)
                lead = int(m_lead.group(1)) if m_lead else 1
                per = 3.0 * rb / max(1, lead) \
                    if dus == "dynamic-update-slice" else 2.0 * rb
                total = per * m
            else:
                tail = op.line.split(op.opcode + "(", 1)[-1].split(")", 1)[0]
                ob = sum(_shape_bytes(defs.get(r, ""))
                         for r in re.findall(r"%([\w\.\-]+)", tail))
                total = (rb + ob) * m
            if total < 1e6:
                continue
            meta = re.search(r'op_name="([^"]+)"', op.line)
            rows.append({
                "opcode": op.opcode,
                "shape": op.result_type.split("{")[0][:48],
                "trips": m,
                "hbm_bytes_total": total,
                "kernel_region": "vmemkernel_" in op.line,
                "source": (meta.group(1)[-110:] if meta else ""),
            })
    rows.sort(key=lambda r: -r["hbm_bytes_total"])
    return rows[:top]


def roofline_terms(counts: RooflineCounts, *, peak_flops: float,
                   hbm_bw: float, ici_bw: float) -> dict:
    """Two memory terms are reported:
    * ``memory_ref_s`` — XLA reference lowering (kernel-region traffic,
      e.g. attention scores, hits HBM);
    * ``memory_s`` — with the Pallas kernels (kernel regions VMEM-resident;
      boundary IO is still counted at the producers outside the region).
    The dominant term / bound use the kernel-adjusted value (the TPU
    target ships the kernels)."""
    compute = counts.flops / peak_flops
    memory = counts.hbm_bytes / hbm_bw
    memory_ref = (counts.hbm_bytes + counts.kernel_region_bytes) / hbm_bw
    collective = counts.link_bytes / ici_bw
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda t: t[1])[0]
    total = max(compute, memory, collective)
    return {
        "compute_s": compute,
        "memory_s": memory,
        "memory_ref_s": memory_ref,
        "collective_s": collective,
        "dominant": dominant,
        "bound_s": total,
    }

"""Gray-failure resilience matrix: {prevote, check_quorum, backoff} on/off
under the gray + corruption scenario tiers.

For each resilience variant x gray scenario x seed, runs the flagship
LeaseGuard policy and records the protocol counters the features exist
to move: term consumption (a flapping node's election storms), leader
evictions while the deposed leader could still reach a quorum (lease
churn from disruptive elections), checksum drops, and the read/write
availability timeline. Writes ``BENCH_gray_matrix.json`` at the repo
root — the headline artifact showing PreVote + CheckQuorum measurably
reduce term inflation and healthy-leader evictions versus the same
seeds with the features off, at zero linearizability violations.

Variants (all on top of the stock matrix RaftParams):

* ``off``        — everything disabled: today's defaults
* ``prevote``    — PreVote only
* ``check_quorum`` — CheckQuorum only
* ``backoff``    — adaptive replication backoff only
* ``all``        — the full resilience tier

Usage:
    python benchmarks/gray_matrix.py [--seeds N] [--smoke] [--jobs N]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import (LinearizabilityError, RaftParams, SimParams,  # noqa: E402
                        check_linearizability, run_workload,
                        throughput_timeline)
from repro.faults import build_scenario  # noqa: E402

REPO_ROOT = Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_gray_matrix.json"
SMOKE_OUT_PATH = REPO_ROOT / "BENCH_gray_matrix_smoke.json"

#: the resilience flag sets under comparison
VARIANTS: dict[str, dict] = {
    "off": {},
    "prevote": {"prevote": True},
    "check_quorum": {"check_quorum": True},
    "backoff": {"replication_backoff": True},
    "all": {"prevote": True, "check_quorum": True,
            "replication_backoff": True},
}

#: the gray + corruption safe tier (every scenario here must stay
#: violation-free for LeaseGuard under every variant)
GRAY_SCENARIOS = [
    "slow_follower", "slow_leader", "flapping_node", "flapping_outbound",
    "gray_combo", "corrupt_entries_checked", "corrupt_storm_checked",
]

POLICY = "leaseguard"
DEFAULT_SEEDS = 10
SIM_DURATION = 1.2
SETTLE_TIME = 1.5
TIMELINE_BIN = 0.1


def run_cell(variant: str, scenario_name: str, seed: int) -> dict:
    """One deterministic run; returns a JSON-ready row."""
    sc = build_scenario(scenario_name)
    raft = RaftParams(election_timeout=0.3, election_jitter=0.1,
                      heartbeat_interval=0.03, lease_duration=0.6,
                      rpc_timeout=0.15,
                      **{**VARIANTS[variant], **sc.raft_overrides})
    sim = SimParams(seed=seed, sim_duration=SIM_DURATION, interarrival=3e-3,
                    write_fraction=1 / 3)
    res = run_workload(raft, sim, fault_script=sc.install, check=False,
                       settle_time=SETTLE_TIME)
    try:
        checked = check_linearizability(res.history)
        violation = None
    except LinearizabilityError as e:
        checked = 0
        violation = str(e)[:200]
    ok = res.reads_ok + res.writes_ok
    fail = res.reads_fail + res.writes_fail
    bins = throughput_timeline(res.history, TIMELINE_BIN, res.t_start,
                               res.t_start + SIM_DURATION + SETTLE_TIME)
    row = {
        "variant": variant,
        "scenario": scenario_name,
        "seed": seed,
        "ops_ok": ok,
        "ops_fail": fail,
        "availability": round(ok / max(1, ok + fail), 4),
        "checked_ops": checked,
        "violation": violation,
        **res.raft_stats,
        # per-node attribution of the summed counters above: WHICH node
        # burned the terms / got evicted (the flapping one, or a healthy
        # victim?) — the summed raft_stats can't say
        "raft_by_node": res.raft_by_node,
        "timeline": {
            "bin_size": TIMELINE_BIN,
            "t0": round(res.t_start, 9),
            "ok": [b["reads"] + b["writes"] for b in bins],
            "fail": [b["read_fail"] + b["write_fail"] for b in bins],
        },
    }
    if violation:
        # identical traced replay -> digest naming the causal election
        from repro.obs.explain import trace_digest
        tres = run_workload(raft, sim,
                            fault_script=build_scenario(scenario_name).install,
                            check=False, settle_time=SETTLE_TIME, trace=True)
        row["trace_digest"] = trace_digest(tres.trace or [],
                                           tres.t_start, tres.t_end)
    return row


def run_matrix(variants: list[str], scenarios: list[str], seeds: list[int],
               jobs: int = 1, progress: bool = True) -> list[dict]:
    """Same deterministic round-robin sharding + ordered merge as
    ``fault_matrix.run_matrix``: byte-identical output for any ``jobs``."""
    cells = [(v, s, seed) for v in variants for s in scenarios
             for seed in seeds]
    if jobs > 1:
        from concurrent.futures import ProcessPoolExecutor
        shards = [cells[k::jobs] for k in range(jobs)]
        with ProcessPoolExecutor(max_workers=jobs) as ex:
            shard_rows = list(ex.map(_run_shard, shards))
        iters = [iter(sr) for sr in shard_rows]
        rows = [next(iters[i % jobs]) for i in range(len(cells))]
    else:
        rows = []
        for i, cell in enumerate(cells):
            rows.append(run_cell(*cell))
            if progress and (i + 1) % 25 == 0:
                print(f"# {i + 1}/{len(cells)} cells", file=sys.stderr)
    rows.sort(key=lambda r: (r["variant"], r["scenario"], r["seed"]))
    return rows


def _run_shard(cells) -> list[dict]:
    return [run_cell(*cell) for cell in cells]


def summarize(rows: list[dict]) -> list[dict]:
    """Per (variant, scenario): the resilience metrics, seed-aggregated."""
    agg: dict[tuple[str, str], dict] = {}
    for r in rows:
        a = agg.setdefault((r["variant"], r["scenario"]), {
            "variant": r["variant"], "scenario": r["scenario"], "seeds": 0,
            "violations": 0, "ops_ok": 0, "ops_fail": 0, "max_term": 0,
            "elections_started": 0, "leader_evictions": 0,
            "healthy_evictions": 0, "quorum_step_downs": 0,
            "checksum_drops": 0,
        })
        a["seeds"] += 1
        a["violations"] += 1 if r["violation"] else 0
        a["ops_ok"] += r["ops_ok"]
        a["ops_fail"] += r["ops_fail"]
        a["max_term"] += r["max_term"]
        for k in ("elections_started", "leader_evictions",
                  "healthy_evictions", "quorum_step_downs",
                  "checksum_drops"):
            a[k] += r[k]
    out = []
    for key in sorted(agg):
        a = agg[key]
        a["mean_max_term"] = round(a.pop("max_term") / a["seeds"], 2)
        a["availability"] = round(
            a["ops_ok"] / max(1, a["ops_ok"] + a["ops_fail"]), 4)
        out.append(a)
    return out


def headline(summary: list[dict]) -> dict:
    """The artifact's claim, made machine-checkable: total term
    consumption and healthy-leader evictions across the gray tier,
    ``off`` vs ``all`` on the same seeds."""
    tot = {v: {"terms": 0.0, "healthy_evictions": 0, "violations": 0}
           for v in ("off", "all")}
    for s in summary:
        if s["variant"] in tot:
            tot[s["variant"]]["terms"] += s["mean_max_term"]
            tot[s["variant"]]["healthy_evictions"] += s["healthy_evictions"]
            tot[s["variant"]]["violations"] += s["violations"]
    return {
        "off": tot["off"],
        "all": tot["all"],
        "term_inflation_reduced": tot["all"]["terms"] < tot["off"]["terms"],
        "healthy_evictions_reduced":
            tot["all"]["healthy_evictions"]
            <= tot["off"]["healthy_evictions"],
    }


class GrayMatrixError(AssertionError):
    """The gray matrix contract failed: a violation under a safe gray/
    corruption scenario, or the resilience tier failed to reduce term
    inflation / healthy-leader evictions."""


def run(quick: bool = False) -> list[dict]:
    """benchmarks.run entry point: full matrix, or the CI smoke slice."""
    return main(["--smoke"] if quick else [])


def main(argv=None) -> list[dict]:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seeds", type=int, default=DEFAULT_SEEDS)
    ap.add_argument("--smoke", action="store_true",
                    help="CI slice: off/all x 2 scenarios x 3 seeds")
    ap.add_argument("--jobs", type=int,
                    default=max(1, (os.cpu_count() or 2) - 1))
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    variants = list(VARIANTS)
    scenarios = list(GRAY_SCENARIOS)
    seeds = list(range(args.seeds))
    if args.smoke:
        variants = ["off", "all"]
        scenarios = ["flapping_node", "corrupt_entries_checked"]
        seeds = list(range(3))
    full_cube = not args.smoke and args.seeds >= DEFAULT_SEEDS
    out_path = args.out or str(OUT_PATH if full_cube else SMOKE_OUT_PATH)

    n = len(variants) * len(scenarios) * len(seeds)
    print(f"# gray matrix: {len(variants)} variants x {len(scenarios)} "
          f"scenarios x {len(seeds)} seeds = {n} cells (jobs={args.jobs})",
          file=sys.stderr)
    rows = run_matrix(variants, scenarios, seeds, jobs=args.jobs)
    summary = summarize(rows)
    head = headline(summary)

    artifact = {
        "policy": POLICY,
        "variants": {v: VARIANTS[v] for v in variants},
        "scenarios": scenarios,
        "seeds": seeds,
        "headline": head,
        "summary": summary,
        "cells": rows,
    }
    Path(out_path).write_text(json.dumps(artifact, indent=2, sort_keys=True)
                              + "\n")
    print(f"# wrote {out_path}", file=sys.stderr)

    for s in summary:
        print(f"{s['variant']:13s} {s['scenario']:26s} "
              f"viol={s['violations']:2d} term={s['mean_max_term']:6.2f} "
              f"evict={s['leader_evictions']:3d} "
              f"healthy_evict={s['healthy_evictions']:3d} "
              f"drops={s['checksum_drops']:4d} "
              f"avail={s['availability']:.3f}")

    bad = [r for r in rows if r["violation"]]
    if bad:
        msg = (f"{len(bad)} linearizability violations under safe "
               f"gray/corruption scenarios")
        print(f"\nFAIL: {msg}", file=sys.stderr)
        for r in bad[:10]:
            print(f"  {r['variant']} / {r['scenario']} / seed {r['seed']}: "
                  f"{r['violation']}", file=sys.stderr)
        raise GrayMatrixError(msg)
    if not args.smoke:
        if not head["term_inflation_reduced"]:
            raise GrayMatrixError(
                f"resilience tier failed to reduce term inflation: "
                f"off={head['off']['terms']} all={head['all']['terms']}")
        if not head["healthy_evictions_reduced"]:
            raise GrayMatrixError(
                "resilience tier failed to reduce healthy-leader "
                f"evictions: off={head['off']['healthy_evictions']} "
                f"all={head['all']['healthy_evictions']}")
    print(f"\n# zero violations; off->all terms "
          f"{head['off']['terms']:.1f}->{head['all']['terms']:.1f}, "
          f"healthy evictions {head['off']['healthy_evictions']}->"
          f"{head['all']['healthy_evictions']}")
    return summary


if __name__ == "__main__":
    try:
        main()
    except GrayMatrixError:
        sys.exit(1)

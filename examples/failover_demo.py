"""Failover availability timeline (paper Fig. 7 as a terminal demo).

Runs the same crash scenario under every configuration in the
consistency-policy registry (including the paper's LeaseGuard ablation
ladder) and prints per-100ms read/write throughput, making the paper's
two availability optimizations visible, then demonstrates elastic
scaling.

Run:  PYTHONPATH=src python examples/failover_demo.py
"""

from repro.consistency import benchmark_configs, split_bench_config
from repro.core import RaftParams, SimParams, run_workload, \
    throughput_timeline

CONFIGS = benchmark_configs()


def crash_at(t):
    def script(cluster):
        cluster.loop.call_later(
            t, lambda: cluster.leader() and cluster.leader().crash())
    return script


def main() -> None:
    print("leader crashes at t=0.5s; ET=0.5s; lease Δ=1.0s "
          "(old lease expires ~t=1.5s)\n")
    for name, config in CONFIGS.items():
        flags, sim_flags = split_bench_config(config)
        raft = RaftParams(election_timeout=0.5, election_jitter=0.1,
                          heartbeat_interval=0.05, lease_duration=1.0,
                          **flags)
        sim = SimParams(seed=7, sim_duration=2.2, interarrival=500e-6,
                        write_fraction=1 / 3, **sim_flags)
        res = run_workload(raft, sim, fault_script=crash_at(0.5),
                           check=name != "inconsistent", settle_time=1.0)
        t0 = min(op.start_ts for op in res.history)
        bins = throughput_timeline(res.history, 0.1, t0, t0 + 2.2)
        reads = "".join("#" if b["reads"] > 100 else
                        ("+" if b["reads"] > 0 else ".") for b in bins)
        writes = "".join("#" if b["writes"] > 40 else
                         ("+" if b["writes"] > 0 else ".") for b in bins)
        print(f"{name:22s} reads  [{reads}]")
        print(f"{'':22s} writes [{writes}]   "
              f"({res.reads_ok}r/{res.writes_ok}w ok, linearizable: "
              f"{res.linearizable_ops} ops checked)")
    print("\nlegend: '#' full throughput, '+' partial, '.' unavailable; "
          "each cell = 100 ms")
    print("note LeaseGuard's read row never goes dark after the election "
          "(inherited leases), and defer_commit's write burst at ~1.5s.")

    # elastic scaling bonus: grow the coordinator under load
    from repro.coord.kvstore import LocalCoordinator
    coord = LocalCoordinator()
    coord.append("cfg", {"v": 1})
    nid = coord.scale_up()
    print(f"\nelastic scaling: replica set grew to "
          f"{sorted(coord._leader().config)} (added node {nid}); "
          f"reads still zero-roundtrip: {coord.read_latest('cfg')}")


if __name__ == "__main__":
    main()

"""The ConsistencyPolicy interface: every consistency decision a node makes.

``Node`` (repro.core.raft) is pure Raft — replication and elections. One
policy instance per node answers the questions the replication core cannot
answer by itself:

* may the commit index advance right now?        ``gate_commit``
* may this client write be accepted right now?   ``gate_write``
* how is a client read served?                   ``gate_read``
* may this RequestVote be granted right now?     ``gate_vote``
* what background upkeep does leadership need?   ``maintenance_task``

plus event notifications (``on_become_leader``, ``on_commit_advanced``,
``on_commit_blocked``, ``on_append_response``) and an RPC extension point
(``on_message``) for policies that speak extra message types — e.g. the
follower-read policy's read-index exchange.

Policies are stateful: mechanism-specific leader state (limbo keys,
heartbeat ack times, in-flight read-index rounds) lives on the policy,
not on the node, and is re-derived in ``on_become_leader``.
"""

from __future__ import annotations

from typing import Any, Optional, TYPE_CHECKING

from ..core.raft import (AppendEntries, AppendEntriesReply, ReadResult,
                         RequestVote)
from ..core.simulate import TimeoutError_, wait_for

if TYPE_CHECKING:  # avoid a runtime import cycle with repro.core.raft
    from ..core.raft import Node


class ConsistencyPolicy:
    """Base class; subclasses override only the hooks they need.

    The defaults are maximally permissive (no lease, no vote delay, no
    commit gate) and ``gate_read`` is abstract — every mechanism must at
    least decide how a read is served.
    """

    #: registry key; must equal the corresponding ``ReadMode`` value.
    name = "base"

    def __init__(self, node: "Node") -> None:
        self.node = node

    # ------------------------------------------------------------ registry
    @classmethod
    def bench_variants(cls) -> dict[str, dict]:
        """Benchmark rows this policy contributes: name -> extra RaftParams
        kwargs. Default: a single row with no extra flags."""
        return {cls.name: {}}

    # ----------------------------------------------------------------- hooks
    def on_become_leader(self) -> None:
        """Called once per election win, after the node's leader volatile
        state is reset and before the election no-op is appended."""

    def gate_commit(self) -> bool:
        """True = the commit index must not advance yet (LeaseGuard's
        commit gate). Queried on every replication ack."""
        return False

    def on_commit_blocked(self) -> None:
        """Called when ``gate_commit`` vetoed a commit advance — the policy
        may schedule a recheck for when the gate should open."""

    def gate_write(self) -> str:
        """Non-empty string = refuse the client write with that error."""
        return ""

    def gate_vote(self, msg: RequestVote) -> bool:
        """True = withhold the vote (Ongaro leases delay elections)."""
        return False

    def on_commit_advanced(self) -> None:
        """Called on the leader after the applied index advanced."""

    def on_append_response(self, peer: int, sent_at: float) -> None:
        """Called on every successful AppendEntries ack; ``sent_at`` is the
        simulated time the RPC was issued (Ongaro's lease input)."""

    def on_quorum_lost(self) -> None:
        """Called just before a CheckQuorum step-down: the leader could
        not reach a voting majority within an election timeout and is
        about to relinquish leadership (and with it, serving its lease).
        Policies drop any leader-local serving state here."""

    def on_message(self, src: int, msg: Any) -> Any:
        """Handle a policy-specific RPC; return the reply or None."""
        return None

    async def maintenance_task(self, epoch: int) -> None:
        """Leader background task (e.g. proactive lease extension).
        Spawned once per leadership epoch; must exit when deposed."""
        return

    async def gate_read(self, key: str) -> ReadResult:
        raise NotImplementedError

    # ------------------------------------------------------- shared helpers
    async def _serve_when_applied(self, key: str, read_index: int,
                                  leader_term: Optional[int] = None,
                                  recheck=None, as_of_index: bool = False,
                                  execution_ts: Optional[float] = None,
                                  ) -> ReadResult:
        """Serve the local value once lastApplied >= ``read_index``. With
        ``leader_term``, abort if this node stops leading that term.
        ``recheck()`` (if given) re-validates the policy's read
        precondition after the wait; returning a ReadResult vetoes.

        ``as_of_index`` cuts the value at ``read_index`` (log-prefix
        state) instead of serving the current applied state, and
        ``execution_ts`` overrides the serve-time linearization point —
        follower reads use both to linearize at the leader's barrier."""
        n = self.node
        deadline = n.loop.now + n.p.read_timeout
        while n.alive:
            if leader_term is not None and (
                    not n.is_leader() or n.term != leader_term):
                return ReadResult(False, error="not_leader")
            if n.last_applied >= read_index:
                if recheck is not None:
                    veto = recheck()
                    if veto is not None:
                        return veto
                if as_of_index:
                    value = [e.value for e in n.log[1:read_index + 1]
                             if not e.is_control and e.key == key]
                else:
                    value = list(n.data.get(key, []))
                return ReadResult(
                    True, value,
                    execution_ts=n.loop.now if execution_ts is None
                    else execution_ts)
            if n.loop.now >= deadline:
                return ReadResult(False, error="timeout")
            await n._cond_wait(deadline)
        return ReadResult(False, error="not_leader")

    async def _local_read(self, key: str, term0: int,
                          recheck=None) -> ReadResult:
        """Wait lastApplied >= commitIndex-at-arrival, then read locally
        (paper Fig. 2 read tail)."""
        return await self._serve_when_applied(
            key, self.node.commit_index, leader_term=term0, recheck=recheck)

    async def _confirm_leadership(self) -> bool:
        """One empty-AppendEntries round: True iff a majority acked and we
        are still the same-term leader (Raft's read barrier)."""
        n = self.node
        tr = n.loop.tracer
        bid = None
        if tr is not None:
            bid = tr.emit("barrier", node=n.id, term=n.term,
                          parent=n._trace_ctx, op="start")
        term0 = n.term
        msg = n._make_append(n.last_log_index, [], n.commit_index)
        futs = [n.net.call(n.id, p, msg) for p in n.peers]
        acks = 1
        deposed = False
        for f in futs:
            try:
                reply: AppendEntriesReply = await wait_for(f, n.p.rpc_timeout)
            except TimeoutError_:
                continue
            if reply.term > n.term:
                n._step_down(reply.term)
                deposed = True
                break
            if reply.success:
                acks += 1
            if acks >= n.majority():
                break
        ok = (not deposed and acks >= n.majority()
              and n.term == term0 and n.is_leader())
        if tr is not None:
            tr.emit("barrier", node=n.id, term=n.term, parent=bid,
                    op="ok" if ok else "fail")
        return ok

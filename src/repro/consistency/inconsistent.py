"""No consistency mechanism: local reads with no lease or barrier.

The paper's lower-bound baseline (§6): reads are as fast as possible and
as wrong as possible — any replica (a deposed leader that has not yet
heard of its successor, a lagging follower) happily serves whatever it
has applied. Useful to bound the cost every real mechanism pays, and the
positive control for the nemesis matrix: under partition scenarios this
policy MUST produce stale reads that ``check_linearizability`` flags.
"""

from __future__ import annotations

from ..core.raft import ReadResult
from .base import ConsistencyPolicy


class InconsistentPolicy(ConsistencyPolicy):
    name = "inconsistent"

    async def gate_read(self, key: str) -> ReadResult:
        n = self.node
        return ReadResult(True, list(n.data.get(key, [])),
                          execution_ts=n.loop.now)

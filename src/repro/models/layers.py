"""Core NN building blocks (pure JAX, functional, pytree params).

Conventions:
* params are nested dicts of jnp arrays; compute dtype bf16, accumulation
  and norms in fp32;
* attention projections are kept FLAT — (d_model, n_heads*head_dim) — so
  tensor-parallel sharding divides the flattened dim regardless of head
  count (heads are reshaped after the matmul);
* the causal-attention reference is **chunked** over queries (bounded
  memory: never materializes the full S×S score matrix), which is also the
  oracle for the Pallas flash-attention kernel.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------- norms
def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


def group_norm_heads(x: jax.Array, w: jax.Array, b: jax.Array,
                     eps: float = 64e-5) -> jax.Array:
    """Per-head LayerNorm (RWKV's group_norm over heads). x: (..., H, hd)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * w + b).astype(x.dtype)


# ---------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def rope_tables(positions: jax.Array, head_dim: int,
                theta: float) -> tuple[jax.Array, jax.Array]:
    """Precomputed (cos, sin), shaped (..., 1, hd/2). positions: (S,) or
    (B, S). Computed ONCE outside the layer scan (loop-invariant)."""
    freqs = rope_freqs(head_dim, theta)                 # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(angles)[..., None, :], jnp.sin(angles)[..., None, :]


def apply_rope(x: jax.Array, rope: tuple[jax.Array, jax.Array]) -> jax.Array:
    """x: (B, S, H, hd); rope = (cos, sin) from rope_tables (broadcasts
    right-aligned against (B, S, H, hd/2))."""
    cos, sin = rope
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------- attention
def repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B, S, Hkv, hd) -> (B, S, Hkv*n_rep, hd)."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)) \
        .reshape(b, s, h * n_rep, d)


def causal_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         window: Optional[int] = None,
                         q_offset: int = 0,
                         chunk: int = 512) -> jax.Array:
    """Chunked causal attention. q: (B,Sq,H,hd), k/v: (B,Sk,H,hd).

    ``q_offset``: absolute position of q[0] relative to k[0] (decode:
    Sk-1). Memory is O(Sq_chunk * Sk), never O(Sq*Sk) at once. Each chunk
    is rematerialized in backward (flash-attention-style: probabilities
    are never stashed across chunks).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    kpos = jnp.arange(sk)

    @partial(jax.checkpoint, prevent_cse=False)
    def attend(q_chunk: jax.Array, qpos: jax.Array) -> jax.Array:
        # q_chunk: (B, C, H, hd); qpos: (C,)
        # named_scope marks this region VMEM-resident on the TPU target:
        # the Pallas flash kernel keeps scores/probs in VMEM, so the
        # roofline analyzer buckets this region's HBM traffic separately
        # (see repro/roofline.py and kernels/flash_attention.py).
        with jax.named_scope("vmemkernel_flash_attention"):
            # bf16 inputs, f32 accumulation (MXU-native): cotangents stay
            # bf16, so the TP gradient all-reduces cross the mesh in bf16
            # (§Perf iteration 2 — halves collective bytes vs f32 casts)
            s = jnp.einsum("bqhd,bkhd->bhqk", q_chunk, k,
                           preferred_element_type=jnp.float32) * scale
            mask = qpos[:, None] >= kpos[None, :]
            if window is not None:
                mask &= qpos[:, None] - kpos[None, :] < window
            s = jnp.where(mask[None, None], s, -jnp.inf)
            p = jax.nn.softmax(s, axis=-1)
            return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                              preferred_element_type=jnp.float32
                              ).astype(q.dtype)

    if sq <= chunk:
        return attend(q, q_offset + jnp.arange(sq))

    n_chunks = (sq + chunk - 1) // chunk
    pad = n_chunks * chunk - sq
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qp = qp.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    pos = (q_offset + jnp.arange(n_chunks * chunk)).reshape(n_chunks, chunk)
    out = jax.lax.map(lambda args: attend(*args), (qp, pos))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * chunk, h, hd)
    return out[:, :sq]


def decode_attention_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         cache_len: jax.Array,
                         window: Optional[int] = None) -> jax.Array:
    """Single-step GQA decode. q: (B,1,H,hd); caches: (B,Smax,Hkv,hd) —
    NOT repeated: query heads are grouped onto their shared KV head
    (§Perf iteration 5b: the repeat_kv broadcast was the dominant decode
    collective/traffic — an f32 all-gather of the whole cache).
    ``cache_len``: #valid entries incl. the new token."""
    b, _, h, hd = q.shape
    hkv = k_cache.shape[2]
    grp = h // hkv
    smax = k_cache.shape[1]
    scale = 1.0 / math.sqrt(hd)
    qg = q[:, 0].reshape(b, hkv, grp, hd)
    with jax.named_scope("vmemkernel_decode_attention"):
        s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                       preferred_element_type=jnp.float32) * scale
        kpos = jnp.arange(smax)
        mask = kpos[None, :] < cache_len[:, None]
        if window is not None:
            mask &= kpos[None, :] >= cache_len[:, None] - window
        s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype),
                         v_cache, preferred_element_type=jnp.float32)
        return out.reshape(b, 1, h, hd).astype(q.dtype)


# ------------------------------------------------------------- MLP
def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jnp.dot(x, w_gate)
    u = jnp.dot(x, w_up)
    return jnp.dot(jax.nn.silu(g) * u, w_down)


# ------------------------------------------------------------- init
def dense_init(key: jax.Array, shape: tuple, dtype, scale: float = 1.0):
    fan_in = shape[0] if len(shape) > 1 else 1
    std = scale / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)

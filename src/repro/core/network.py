"""Simulated message-passing network with delays, partitions, and node I/O.

Two latency components model the paper's experiments:

* **network delay**: lognormal one-way latency per message (paper §6.4 uses
  mean 1–10 ms for the latency study; §6.5 uses AWS same-subnet stats,
  mean 191 µs, variance 391 µs²-scaled).
* **I/O service time**: each node serializes outgoing message processing
  through a single queue with a per-message service time. This models the
  disk/NIC contention that makes quorum reads fight with replication for
  I/O — the effect behind the paper's ~10x write-throughput gap (Figs. 9-11)
  and the queueing blow-up in Fig. 10.

RPC layer: ``call()`` returns a Future for the reply, with timeout. One-way
``send()`` is also available.

Fault injection (the nemesis engine, ``repro.faults``) drives three knobs:

* **directional cuts**: partitions are stored per directed link, so
  asymmetric (one-way) partitions are expressible; the classic
  ``partition(a, b)`` cuts both directions.
* **message faults**: composable :class:`MessageFault` rules add extra
  delay, reorder jitter, probabilistic loss, and duplication, globally or
  per directed link.
* **I/O slowdown**: per-node extra service time on top of
  ``NetParams.io_service_time``.

With no faults installed the PRNG draw sequence is exactly the historical
one (one lognormal per transmission), so pre-nemesis seeds replay
bit-identically.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from .prob import PRNG
from .simulate import EventLoop, Future, Timer, TimeoutError_, wait_for


@dataclass(slots=True)
class NetParams:
    one_way_latency_mean: float = 191e-6
    one_way_latency_variance: float = 391e-6 ** 2
    io_service_time: float = 0.0       # per outgoing message, serialized per node
    rpc_timeout: float = 0.5


@dataclass(slots=True)
class MessageFault:
    """One active message-perturbation rule.

    ``src``/``dst`` of ``None`` match any sender/receiver; both set
    restricts the rule to that directed link. Multiple installed rules
    compose: delays and jitter add, drop/duplicate draws are independent.
    """

    extra_delay: float = 0.0    # deterministic added one-way latency
    jitter: float = 0.0         # uniform extra in [0, jitter] -> reordering
    drop_prob: float = 0.0      # iid loss per message
    dup_prob: float = 0.0       # iid duplication per message
    src: Optional[int] = None
    dst: Optional[int] = None

    def matches(self, src: int, dst: int) -> bool:
        return (self.src is None or self.src == src) and \
               (self.dst is None or self.dst == dst)


class Network:
    __slots__ = ("loop", "prng", "params", "_handlers", "_cut", "_down",
                 "_io_busy_until", "_io_slow", "_faults", "_fault_seq",
                 "_intercept", "_intercept_seq",
                 "_rpc_seq", "_pending", "_reaps", "messages_sent",
                 "bytes_sent", "messages_delivered", "messages_dropped",
                 "_lat_mu", "_lat_sigma")

    def __init__(self, loop: EventLoop, prng: PRNG, params: NetParams) -> None:
        self.loop = loop
        self.prng = prng
        self.params = params
        self._handlers: dict[int, Callable[[int, Any], Any]] = {}
        self._cut: set[tuple[int, int]] = set()   # directed blocked links
        self._down: set[int] = set()
        self._io_busy_until: dict[int, float] = {}
        self._io_slow: dict[int, float] = {}      # per-node extra service time
        self._faults: dict[int, MessageFault] = {}
        self._fault_seq = 0
        # delivery interceptors: fn(src, dst, msg) -> msg' (possibly a
        # mutated copy) or None to drop. Applied at delivery time to both
        # requests and replies; with none installed the delivery path is
        # untouched (zero extra PRNG draws).
        self._intercept: dict[int, Callable[[int, int, Any], Any]] = {}
        self._intercept_seq = 0
        self._rpc_seq = 0
        self._pending: dict[int, Future] = {}
        self._reaps: dict[int, "Timer"] = {}      # rid -> pending-reap timer
        self.messages_sent = 0
        self.bytes_sent = 0
        self.messages_delivered = 0
        self.messages_dropped = 0                 # unreachable at delivery
        # the latency distribution is fixed per run: precompute the
        # underlying normal's (mu, sigma) once instead of per message (the
        # draw itself is unchanged — same lognormvariate call, same stream)
        mean, var = params.one_way_latency_mean, params.one_way_latency_variance
        if mean > 0:
            sigma2 = math.log(1.0 + var / (mean * mean))
            self._lat_mu = math.log(mean) - sigma2 / 2.0
            self._lat_sigma = math.sqrt(sigma2)
        else:
            self._lat_mu = None
            self._lat_sigma = 0.0

    # -- topology ----------------------------------------------------------
    def register(self, node_id: int, handler: Callable[[int, Any], Any]) -> None:
        """handler(src, msg) -> reply or None; called on delivery."""
        self._handlers[node_id] = handler

    def partition(self, a: int, b: int) -> None:
        self._cut.add((a, b))
        self._cut.add((b, a))

    def partition_oneway(self, src: int, dst: int) -> None:
        """Cut only src -> dst; dst can still reach src."""
        self._cut.add((src, dst))

    def heal(self, a: int = -1, b: int = -1) -> None:
        if a < 0:
            self._cut.clear()
        else:
            self._cut.discard((a, b))
            self._cut.discard((b, a))

    def heal_oneway(self, src: int, dst: int) -> None:
        self._cut.discard((src, dst))

    def set_down(self, node_id: int, down: bool = True) -> None:
        if down:
            self._down.add(node_id)
        else:
            self._down.discard(node_id)

    def reachable(self, src: int, dst: int) -> bool:
        return (
            src not in self._down
            and dst not in self._down
            and (src, dst) not in self._cut
        )

    # -- fault knobs ---------------------------------------------------------
    def add_fault(self, fault: MessageFault) -> int:
        """Install a message-perturbation rule; returns a removal handle."""
        self._fault_seq += 1
        self._faults[self._fault_seq] = fault
        return self._fault_seq

    def remove_fault(self, handle: int) -> None:
        self._faults.pop(handle, None)

    def add_interceptor(self, fn: Callable[[int, int, Any], Any]) -> int:
        """Install a delivery interceptor ``fn(src, dst, msg) -> msg|None``;
        returning a different object substitutes it (field-level corruption),
        returning None drops the message. Returns a removal handle."""
        self._intercept_seq += 1
        self._intercept[self._intercept_seq] = fn
        return self._intercept_seq

    def remove_interceptor(self, handle: int) -> None:
        self._intercept.pop(handle, None)

    def _apply_interceptors(self, src: int, dst: int, msg: Any) -> Any:
        for handle in sorted(self._intercept):
            msg = self._intercept[handle](src, dst, msg)
            if msg is None:
                return None
        return msg

    def set_io_slowdown(self, node_id: int, extra_service_time: float) -> None:
        """Extra per-message I/O service time for one node (0 clears)."""
        if extra_service_time > 0.0:
            self._io_slow[node_id] = extra_service_time
        else:
            self._io_slow.pop(node_id, None)

    # -- I/O serialization ---------------------------------------------------
    def _io_delay(self, node_id: int) -> float:
        """Serialize a node's message processing through one I/O queue."""
        svc = self.params.io_service_time + self._io_slow.get(node_id, 0.0)
        if svc <= 0:
            return 0.0
        start = max(self.loop.now, self._io_busy_until.get(node_id, 0.0))
        self._io_busy_until[node_id] = start + svc
        return (start + svc) - self.loop.now

    def _latency_draw(self) -> float:
        """One lognormal network-latency sample (precomputed mu/sigma)."""
        if self._lat_mu is None:
            return 0.0
        return self.prng.lognormvariate(self._lat_mu, self._lat_sigma)

    def _delivery_delays(self, src: int, dst: int) -> list[float]:
        """One delay per delivered copy of a message on src -> dst; empty
        list = dropped in flight. Matches the historical single-lognormal
        draw exactly when no fault rules are installed."""
        io = self._io_delay(src)
        base = io + self._latency_draw()
        if not self._faults:
            return [base]
        copies = 1
        extra = 0.0
        jitter = 0.0
        for handle in sorted(self._faults):
            f = self._faults[handle]
            if not f.matches(src, dst):
                continue
            if f.drop_prob > 0.0 and self.prng.random() < f.drop_prob:
                return []
            if f.dup_prob > 0.0 and self.prng.random() < f.dup_prob:
                copies += 1
            extra += f.extra_delay
            jitter += f.jitter
        delays = []
        for i in range(copies):
            d = base if i == 0 else io + self._latency_draw()
            d += extra
            if jitter > 0.0:
                d += self.prng.uniform(0.0, jitter)
            delays.append(d)
        return delays

    # -- messaging -----------------------------------------------------------
    def send(self, src: int, dst: int, msg: Any, size: int = 256) -> None:
        """Fire-and-forget delivery (reply, if any, is discarded)."""
        self._transmit(src, dst, msg, size, reply_to=None)

    def call(self, src: int, dst: int, msg: Any, size: int = 256,
             timeout: Optional[float] = None) -> "Future":
        """RPC: deliver msg; handler's return value resolves the future."""
        self._rpc_seq += 1
        rid = self._rpc_seq
        fut = Future(self.loop)
        self._pending[rid] = fut
        # reap the pending entry well after every caller has timed out, so
        # dropped messages (partitions, loss faults) don't leak futures;
        # the reap timer is cancelled on the fast path (reply delivered)
        self._reaps[rid] = self.loop.call_later_cancelable(
            4 * self.params.rpc_timeout, lambda: self._reap_rpc(rid))
        self._transmit(src, dst, msg, size, reply_to=rid)
        return fut

    def _reap_rpc(self, rid: int) -> None:
        self._pending.pop(rid, None)
        self._reaps.pop(rid, None)

    async def call_wait(self, src: int, dst: int, msg: Any, size: int = 256,
                        timeout: Optional[float] = None) -> Any:
        t = timeout if timeout is not None else self.params.rpc_timeout
        return await wait_for(self.call(src, dst, msg, size), t)

    def _transmit(self, src: int, dst: int, msg: Any, size: int,
                  reply_to: Optional[int]) -> None:
        self.messages_sent += 1
        self.bytes_sent += size

        def deliver() -> None:
            if not self.reachable(src, dst):
                self.messages_dropped += 1
                return  # dropped; RPC future times out at caller
            handler = self._handlers.get(dst)
            if handler is None:
                return
            m = msg
            if self._intercept:
                m = self._apply_interceptors(src, dst, m)
                if m is None:
                    self.messages_dropped += 1
                    return
            self.messages_delivered += 1
            reply = handler(src, m)
            if reply_to is not None and reply is not None:
                # reply travels back with its own I/O + network delay (and
                # is subject to the same loss/duplication faults)
                for rdelay in self._delivery_delays(dst, src):
                    def deliver_reply() -> None:
                        if not self.reachable(dst, src):
                            self.messages_dropped += 1
                            return
                        r = reply
                        if self._intercept:
                            r = self._apply_interceptors(dst, src, r)
                            if r is None:
                                self.messages_dropped += 1
                                return
                        fut = self._pending.pop(reply_to, None)
                        timer = self._reaps.pop(reply_to, None)
                        if timer is not None:
                            timer.cancel()
                        if fut is not None and not fut.done():
                            self.messages_delivered += 1
                            fut.set_result(r)

                    self.loop.call_later(rdelay, deliver_reply)

        for delay in self._delivery_delays(src, dst):
            self.loop.call_later(delay, deliver)

"""Offline invariant probes over recorded traces.

:func:`at_most_one_lease_holder` re-derives LeaseGuard's safety argument
(paper §3) from lease events alone — a second, independent check beside
the omniscient linearizability checker. The linearizability checker
looks at client histories; this probe looks at the *mechanism*: the
serving windows the lease machinery actually granted.

Window model
------------

Every ``lease`` event with op ``acquire``/``extend`` opens a serving
window ``[t, until]``: the emitting leader may serve local reads from
event time ``t`` until true time ``until = entry.interval.latest + Δ``
(an upper bound — the node's own bounded clock forces it to stop no
later than that). A window is **exclusive** when ``entry_term == term``:
it is backed by an entry of the holder's own term, so the holder may
also commit new writes under it. Inherited windows (``entry_term <
term``, §3.3) are backed by the *prior* leadership's entry — both
leaders serve the identical committed prefix, so their overlap is safe
by construction and exempt.

Invariants checked:

1. **one leader per term**: two different nodes never emit lease windows
   at the same term;
2. **exclusive windows never overlap across terms on different nodes**:
   the first own-term-backed window of term T2 must open strictly after
   every earlier term's serving deadline — exactly what the commit gate
   (Fig. 2) enforces via ``definitelyOlderThan`` — unless the earlier
   leadership *relinquished* (committed END_LEASE, §5.1 planned
   handover) before T2's window opened.

On traces of expect-safe scenarios with a consistent policy the probe
must return no violations; under unsafe faults (lying clocks, disk
wipes) a violation is a *finding* that localizes exactly which two
leaderships' windows overlapped and by how much.
"""

from __future__ import annotations


def at_most_one_lease_holder(events: list) -> list[dict]:
    """Return the list of violations (empty = invariant holds).

    Each violation dict carries ``check``, the two (node, term) pairs
    involved, and the overlap evidence.
    """
    violations: list[dict] = []
    nodes_by_term: dict[int, set] = {}
    # term -> [t_first_exclusive, until_max, node]
    excl: dict[int, list] = {}
    relinquished: dict[int, float] = {}

    for e in events:
        if e["type"] != "lease":
            continue
        op = e["op"]
        if op == "relinquish":
            t = relinquished.get(e["term"])
            relinquished[e["term"]] = e["t"] if t is None else min(t, e["t"])
            continue
        if op not in ("acquire", "extend"):
            continue
        term = e["term"]
        nodes_by_term.setdefault(term, set()).add(e["node"])
        if e["entry_term"] == term:
            w = excl.get(term)
            if w is None:
                excl[term] = [e["t"], e["until"], e["node"]]
            else:
                w[0] = min(w[0], e["t"])
                w[1] = max(w[1], e["until"])

    for term, nodes in sorted(nodes_by_term.items()):
        if len(nodes) > 1:
            violations.append({
                "check": "one_leader_per_term", "term": term,
                "nodes": sorted(nodes),
                "detail": f"lease windows at term {term} emitted by "
                          f"{len(nodes)} different nodes"})

    terms = sorted(excl)
    for i, t2 in enumerate(terms):
        start2, _, node2 = excl[t2]
        for t1 in terms[:i]:
            start1, until1, node1 = excl[t1]
            if node1 == node2:
                continue        # one process cannot serve concurrently
            if relinquished.get(t1) is not None \
                    and relinquished[t1] <= start2:
                continue        # planned handover: window ended early
            if start2 < until1 - 1e-9:
                violations.append({
                    "check": "exclusive_window_overlap",
                    "holder_a": {"node": node1, "term": t1,
                                 "window": [start1, until1]},
                    "holder_b": {"node": node2, "term": t2,
                                 "opened_at": start2},
                    "overlap": until1 - start2,
                    "detail": f"node {node2} opened an own-term lease "
                              f"window at t={start2:.6f} (term {t2}) while "
                              f"node {node1}'s term-{t1} window was still "
                              f"valid until t={until1:.6f}"})
    return violations

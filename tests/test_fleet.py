"""Fleet simulator: lineage safety under chaos, chief failover, the
positive control, determinism, and the leaseguard vs quorum load gap."""

from __future__ import annotations

import pytest

from repro.consistency import resolve_read_mode
from repro.core import RaftParams, SimParams
from repro.fleet import (FleetParams, FleetScenario, build_fleet_scenario,
                         check_lineage, fleet_scenario_names, run_fleet)


def raftp(policy: str) -> RaftParams:
    return RaftParams(n_nodes=3, read_mode=resolve_read_mode(policy),
                      election_timeout=0.3, election_jitter=0.1,
                      heartbeat_interval=0.03, lease_duration=0.6,
                      rpc_timeout=0.15)


def fleet_run(policy: str, scenario: str, seed: int, **fp):
    return run_fleet(raftp(policy), SimParams(seed=seed),
                     FleetParams(**fp), build_fleet_scenario(scenario))


def test_calm_fleet_trains_and_checkpoints():
    res = fleet_run("leaseguard", "calm", seed=1)
    assert res.violations == []
    assert res.n_claims == 1                    # one chief, never deposed
    assert res.n_manifests == res.n_valid_manifests > 10
    assert res.total_steps > 1000
    assert res.polls_failed == 0 and res.stale_polls == 0
    # every worker boot-restored exactly once, plus the chief's takeover
    boots = [r for r in res.restores_detail if r["kind"] == "boot"]
    assert len(boots) == 8


def test_chief_kill_elects_successor():
    res = fleet_run("leaseguard", "chief_kill", seed=1)
    assert res.violations == []
    assert len(res.chief_deaths) == 1
    d = res.chief_deaths[0]
    assert d["recovery_time"] is not None       # a successor committed
    assert d["steps_lost"] >= 0
    assert res.n_claims >= 2                    # takeover claimed a new epoch
    takeovers = [r for r in res.restores_detail if r["kind"] == "takeover"]
    assert len(takeovers) >= 2


def test_worker_crashes_rejoin_and_restore():
    res = fleet_run("leaseguard", "worker_crashes", seed=2)
    assert res.violations == []
    rejoins = [r for r in res.restores_detail if r["kind"] == "rejoin"]
    assert rejoins, "crashed workers must restore on rejoin"
    for r in rejoins:
        assert r["manifest"] is not None        # restored a real checkpoint


def test_leader_crash_mid_commit_keeps_lineage():
    for policy in ("leaseguard", "quorum"):
        res = fleet_run(policy, "leader_crash_mid_commit", seed=1)
        assert res.violations == [], (policy, res.violations)
        assert res.n_manifests > 50             # the storm really stormed


def test_chief_and_leader_die_together():
    res = fleet_run("leaseguard", "chief_and_leader_die", seed=3)
    assert res.violations == []
    assert len(res.chief_deaths) == 1


def test_stragglers_flagged_by_registry():
    res = fleet_run("leaseguard", "straggler_band", seed=1)
    assert res.violations == []
    flagged = {w for w, slow in res.straggler_flags.items() if slow}
    assert flagged, "4x-slow workers must trip the straggler table"
    assert len(flagged) <= 3                    # and only the slowed band


def test_inconsistent_positive_control():
    hits = []
    for seed in (1, 3):
        res = fleet_run("inconsistent", "partition_churn", seed=seed,
                        read_any_fraction=0.3)
        hits.extend(res.violations)
    assert hits, "stale replicas must produce lineage violations"
    assert all(v["check"] in ("stale_restore", "fork", "durability")
               for v in hits)


def test_fleet_run_deterministic():
    a = fleet_run("leaseguard", "chief_kill", seed=2)
    b = fleet_run("leaseguard", "chief_kill", seed=2)
    assert a.summarize() == b.summarize()
    assert a.total_steps == b.total_steps
    assert a.messages == b.messages


def test_leaseguard_poll_load_much_lighter_than_quorum():
    lg = fleet_run("leaseguard", "calm", seed=1)
    qr = fleet_run("quorum", "calm", seed=1)
    assert lg.violations == [] and qr.violations == []
    assert lg.messages_per_step * 2 < qr.messages_per_step


def test_checkpoint_storm_floods_manifests():
    calm = fleet_run("leaseguard", "calm", seed=1)
    storm = fleet_run("leaseguard", "checkpoint_storm", seed=1)
    assert storm.violations == []
    assert storm.n_manifests > 3 * calm.n_manifests


def test_fleet_scenario_refuses_plain_install():
    sc = build_fleet_scenario("calm")
    assert isinstance(sc, FleetScenario)
    with pytest.raises(RuntimeError):
        sc.install(object())


def test_scenario_registry_names():
    names = fleet_scenario_names()
    assert "calm" in names and "partition_churn" in names
    assert "leader_crash_mid_commit" in names   # combined control+data


# ------------------------------------------------ checker unit tests
def _man(epoch, chief, step, ts, parent=None):
    return ({"kind": "manifest", "epoch": epoch, "chief": chief,
             "step": step, "parent": parent if parent is not None else step,
             "id": f"{chief}:{epoch}:{step}"}, ts)


def _claim(epoch, chief, ts):
    return ({"kind": "claim", "epoch": epoch, "chief": chief}, ts)


def test_checker_fencing_invalidates_deposed_chief():
    entries = [_claim(1, "w0", 0.1), _man(1, "w0", 5, 0.2),
               _claim(2, "w1", 0.3),
               _man(1, "w0", 10, 0.4),         # deposed chief: fenced out
               _man(2, "w1", 7, 0.5)]
    assert check_lineage(entries, []) == []


def test_checker_catches_fork():
    entries = [_claim(1, "w0", 0.1), _man(1, "w0", 10, 0.2),
               _claim(2, "w1", 0.3), _man(2, "w1", 4, 0.4)]
    v = check_lineage(entries, [])
    assert [x["check"] for x in v] == ["fork"]


def test_checker_catches_stale_restore():
    entries = [_claim(1, "w0", 0.1), _man(1, "w0", 5, 0.2),
               _man(1, "w0", 10, 0.3)]
    stale = {"wid": "w3", "kind": "rejoin", "t_start": 1.0, "t_end": 1.1,
             "manifest": entries[1][0]}        # saw step 5, bound is 10
    v = check_lineage(entries, [stale])
    assert [x["check"] for x in v] == ["stale_restore"]
    fresh = {"wid": "w3", "kind": "rejoin", "t_start": 1.0, "t_end": 1.1,
             "manifest": entries[2][0]}
    assert check_lineage(entries, [fresh]) == []


def test_checker_catches_phantom_restore():
    entries = [_claim(1, "w0", 0.1), _man(1, "w0", 5, 0.2)]
    phantom = {"wid": "w1", "kind": "boot", "t_start": 0.3, "t_end": 0.4,
               "manifest": {"kind": "manifest", "epoch": 9, "chief": "wx",
                            "step": 99, "parent": 0, "id": "wx:9:99"}}
    v = check_lineage(entries, [phantom])
    assert any(x["check"] == "durability" for x in v)

"""Activation-sharding context: lets model code place logical constraints
(batch→dp, feature→tp) without knowing the mesh.

§Perf iteration 1 (see EXPERIMENTS.md): without these constraints GSPMD
resolves ``x[batch@dp] @ w[in@dp, out@tp]`` by UN-sharding the batch and
all-reducing full-microbatch f32 partials (observed: 1.5-20 TB of
all-reduce per step). Constraining projection outputs to
``P(dp, None, tp)`` forces the cheap resolution: weights are all-gathered
over dp (the FSDP gather), activations stay batch-sharded, and the only
activation collectives left are the canonical Megatron-style TP
all-reduces.

The context is process-global (set by the launcher/dry-run before
tracing); when unset every constraint is a no-op, so CPU unit tests and
single-device runs are unaffected.
"""

from __future__ import annotations

from typing import Optional, Union

import jax
from jax.sharding import PartitionSpec as P

_DP: Optional[Union[str, tuple]] = None
_TP: Optional[str] = None
_SP: bool = False    # Megatron-style sequence parallelism (§Perf iter 3):
#                      residual stream sharded over 'model' on the seq dim
#                      between blocks; TP all-reduces become RS+AG pairs
#                      (half the link bytes) and norms/elementwise shard 16x.


def set_axes(dp, tp, sp: bool = False) -> None:
    global _DP, _TP, _SP
    _DP, _TP, _SP = dp, tp, sp


def clear() -> None:
    set_axes(None, None, False)


def sp_enabled() -> bool:
    return _SP and _TP is not None


_MOE_GROUPS: int = 1


def set_moe_groups(n: int) -> None:
    """Number of dispatch groups for group-local MoE (usually the dp
    extent; 1 = flat dispatch)."""
    global _MOE_GROUPS
    _MOE_GROUPS = max(1, n)


def moe_groups() -> int:
    return _MOE_GROUPS


def axes_from_mesh(mesh) -> tuple:
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    tp = "model" if "model" in mesh.axis_names else None
    return dp, tp


def constrain(x: jax.Array, *roles: Optional[str]) -> jax.Array:
    """roles: one of 'dp' | 'tp' | None per dim (trailing dims may be
    omitted). No-op when no mesh context is set."""
    if _DP is None and _TP is None:
        return x
    spec = []
    for i in range(x.ndim):
        role = roles[i] if i < len(roles) else None
        if role == "dp":
            spec.append(_DP)
        elif role == "tp":
            spec.append(_TP)
        elif role == "sp":
            spec.append(_TP if _SP else None)
        else:
            spec.append(None)
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError):
        return x  # dim not divisible / no mesh: leave unconstrained

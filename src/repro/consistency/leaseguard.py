"""LeaseGuard: the log is the lease (paper §3, Fig. 2).

Entries carry ``intervalNow()`` from the writing leader's
bounded-uncertainty clock. The three pieces:

* **commit gate** (Fig. 2 CommitEntry): a new leader must not commit
  while any prior-term entry is possibly < Δ old — O(1) via a cached
  newest-prior-term index (§7.1);
* **read gate**: reads are local while the newest committed entry is
  provably < Δ old, with the limbo-region check for inherited leases
  (§3.3) — keys written between the old leader's last advertised
  commitIndex and its last appended entry cannot be served until an
  own-term entry commits;
* **optimizations** (§3.2/§3.3): deferred-commit writes (accept and
  replicate during the old lease, ack when it expires) and
  inherited-lease reads, each behind a RaftParams flag so the paper's
  log_lease / defer_commit ablations are this same policy.
"""

from __future__ import annotations

from ..core.raft import END_LEASE, NOOP, ReadResult
from .base import ConsistencyPolicy


class LeaseGuardPolicy(ConsistencyPolicy):
    name = "leaseguard"

    def __init__(self, node) -> None:
        super().__init__(node)
        self.limbo_keys: set[str] = set()
        self.last_prior_term_index = 0
        self._recheck_scheduled = False

    @classmethod
    def bench_variants(cls) -> dict[str, dict]:
        # the paper's Figs. 7/9 ablation ladder
        return {
            "log_lease": dict(defer_commit_writes=False,
                              inherited_lease_reads=False),
            "defer_commit": dict(defer_commit_writes=True,
                                 inherited_lease_reads=False),
            "leaseguard": {},
        }

    # ------------------------------------------------------------ leadership
    def on_become_leader(self) -> None:
        n = self.node
        # limbo region: (commitIndex, last log index at election]  (§3.3)
        self.limbo_keys = {
            n.log[i].key
            for i in range(n.commit_index + 1, n.last_index_at_election + 1)
            if not n.log[i].is_control
        }
        # O(1) commit-gate cache (§7.1): newest prior-term entry
        self.last_prior_term_index = 0
        for i in range(n.last_log_index, -1, -1):
            if n.log[i].term < n.term:
                self.last_prior_term_index = i
                break
        tr = n.loop.tracer
        if tr is not None:
            # window derived from values already in hand — no clock reads,
            # so tracing never perturbs the PRNG draw order
            e = n.log[n.commit_index]
            tr.emit("lease", node=n.id, term=n.term, parent=n._trace_ctx,
                    op="acquire", entry_term=e.term,
                    until=e.interval.latest + n.p.delta,
                    limbo=len(self.limbo_keys))

    # ------------------------------------------------------------ commit gate
    def gate_commit(self) -> bool:
        n = self.node
        i = self.last_prior_term_index
        if i == 0:
            return False
        e = n.log[i]
        if e.key == END_LEASE and \
                e.term == n.log[n.last_index_at_election].term:
            # planned handover (§5.1): prior leader relinquished its lease.
            return False
        return not n.clock.definitely_older_than(e.interval, n.p.delta)

    def on_commit_blocked(self) -> None:
        if self._recheck_scheduled:
            return
        self._recheck_scheduled = True
        n = self.node
        e = n.log[self.last_prior_term_index]
        tr = n.loop.tracer
        if tr is not None:
            tr.emit("lease", node=n.id, term=n.term, parent=n._trace_ctx,
                    op="gate_blocked", entry_term=e.term,
                    until=e.interval.latest + n.p.delta)
        eta = max(0.0, e.interval.latest + n.p.delta - n.loop.now) \
            + 2 * n.clock.max_error + 1e-6

        def recheck() -> None:
            self._recheck_scheduled = False
            n._try_advance_commit()

        n.loop.call_later(eta, recheck)

    def gate_write(self) -> str:
        if not self.node.p.defer_commit_writes and self.gate_commit():
            # unoptimized log-based lease: refuse writes during the old lease
            return "no_lease"
        return ""

    def on_commit_advanced(self) -> None:
        n = self.node
        if self.limbo_keys and n.log[n.commit_index].term == n.term:
            self.limbo_keys = set()  # own-term commit ends limbo
        tr = n.loop.tracer
        if tr is not None:
            e = n.log[n.commit_index]
            tr.emit("lease", node=n.id, term=n.term, parent=n._trace_ctx,
                    op="extend", entry_term=e.term,
                    until=e.interval.latest + n.p.delta)

    def holds_lease(self) -> bool:
        """Invariant probe (tests only): could this node serve a local read
        right now, ignoring limbo keys? True iff it is the leader and the
        newest committed entry's lease is still valid under its own
        bounded-uncertainty clock. Safety demands this is never
        simultaneously true on two nodes."""
        n = self.node
        return (n.alive and n.is_leader()
                and n.clock.lease_valid(n.log[n.commit_index].interval,
                                        n.p.delta))

    # -------------------------------------------------------------- read gate
    def _read_barrier(self, key: str) -> str:
        """Lease + limbo checks; non-empty string = reject reason."""
        n = self.node
        e = n.log[n.commit_index]
        if not n.clock.lease_valid(e.interval, n.p.delta):
            return "no_lease"
        if e.term != n.term:
            # inherited lease (§3.3)
            if not n.p.inherited_lease_reads:
                return "no_lease"
            if key in self.limbo_keys:
                return "limbo"
        return ""

    async def gate_read(self, key: str) -> ReadResult:
        n = self.node
        if not n.is_leader():
            return ReadResult(False, error="not_leader")
        err = self._read_barrier(key)
        if err:
            return ReadResult(False, error=err)
        term0 = n.term

        def recheck():
            e2 = self._read_barrier(key)
            return ReadResult(False, error=e2) if e2 else None

        return await self._local_read(key, term0, recheck=recheck)

    # ------------------------------------------------------------ lease upkeep
    async def maintenance_task(self, epoch: int) -> None:
        """Proactive lease extension (§5.1): append a no-op before expiry."""
        n = self.node
        if not n.p.lease_maintenance:
            return
        interval = max(n.p.delta / 4.0, 2 * n.p.heartbeat_interval)
        while n.alive and n.state == "leader" and n._leader_epoch == epoch:
            await n.loop.sleep(interval)
            if not (n.alive and n.state == "leader"
                    and n._leader_epoch == epoch):
                return
            e = n.log[n.commit_index]
            # refresh when the lease is past half its life and nothing newer
            # is in flight to extend it
            if n.last_log_index == n.commit_index and \
                    n.clock.possibly_older_than(e.interval, n.p.delta / 2):
                n._append_local(NOOP, None)

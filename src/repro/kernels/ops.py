"""Jit'd dispatch wrappers for the Pallas kernels.

``impl`` selects the path:
  * "pallas"            real Mosaic lowering (TPU runtime)
  * "pallas_interpret"  kernel body executed on CPU (correctness tests)
  * "reference"         pure-jnp oracle (dry-run lowering; the roofline
                        analyzer's vmemkernel_* scopes account for the
                        VMEM-residency the Pallas path provides on TPU)
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .decode_attention import flash_decode as _flash_decode
from .flash_attention import flash_attention_fwd
from .rwkv6 import wkv6_chunked

DEFAULT_IMPL = "reference"


@partial(jax.jit, static_argnames=("window", "impl", "block_q", "block_k"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    window: Optional[int] = None,
                    impl: str = DEFAULT_IMPL,
                    block_q: int = 128, block_k: int = 128) -> jax.Array:
    """q: (BH, Sq, hd); k/v: (BHkv, Sk, hd)."""
    if impl == "reference":
        return ref.flash_attention_ref(q, k, v, window=window)
    return flash_attention_fwd(q, k, v, window=window, block_q=block_q,
                               block_k=block_k,
                               interpret=(impl == "pallas_interpret"))


@partial(jax.jit, static_argnames=("impl", "block_s"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, *, impl: str = DEFAULT_IMPL,
                     block_s: int = 256) -> jax.Array:
    """Flash decode. q: (BHkv, grp, hd); caches: (BHkv, S, hd);
    cache_len: (BHkv,)."""
    if impl == "reference":
        bhkv, grp, hd = q.shape
        qr = q.reshape(bhkv, 1, grp, hd).transpose(0, 1, 2, 3)
        # reference expects (B, 1, H, hd) + (B, S, Hkv, hd); here each
        # BHkv row is its own batch entry with one kv head
        from ..models.layers import decode_attention_ref
        out = decode_attention_ref(qr, k_cache[:, :, None, :],
                                   v_cache[:, :, None, :], cache_len)
        return out[:, 0]
    return _flash_decode(q, k_cache, v_cache, cache_len, block_s=block_s,
                         interpret=(impl == "pallas_interpret"))


@partial(jax.jit, static_argnames=("impl", "chunk"))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, *, impl: str = DEFAULT_IMPL,
         chunk: int = 64) -> jax.Array:
    """r,k,v,w: (BH, S, hd); u: (BH, hd) — fp32 recurrence."""
    if impl == "reference":
        return ref.wkv6_ref(r, k, v, w, u)
    return wkv6_chunked(r.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), w.astype(jnp.float32),
                        u.astype(jnp.float32), chunk=chunk,
                        interpret=(impl == "pallas_interpret"))

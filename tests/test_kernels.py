"""Pallas kernel allclose tests: interpret-mode kernel vs pure-jnp oracle,
swept over shapes/dtypes (GQA ratios, ragged sequence vs block, sliding
windows, chunk sizes)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.rwkv6 import wkv6_chunked


def rand(key, shape, dtype, scale=0.5):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


FA_CASES = [
    # (BH, BHkv, S, hd, window, block_q, block_k, dtype)
    (4, 4, 128, 64, None, 64, 64, jnp.float32),      # MHA
    (8, 2, 256, 64, None, 64, 64, jnp.float32),      # GQA 4x
    (6, 2, 192, 32, None, 64, 64, jnp.float32),      # ragged: S % block != 0
    (4, 4, 256, 64, 64, 64, 64, jnp.float32),        # sliding window
    (4, 2, 256, 128, None, 128, 128, jnp.float32),   # MXU-aligned hd
    (4, 4, 128, 64, None, 32, 128, jnp.float32),     # bq != bk
    (4, 2, 128, 64, None, 64, 64, jnp.bfloat16),     # bf16 io
    (2, 1, 512, 64, 128, 128, 64, jnp.bfloat16),     # window + bf16
]


@pytest.mark.parametrize("bh,bhkv,s,hd,window,bq,bk,dtype", FA_CASES)
def test_flash_attention_matches_oracle(bh, bhkv, s, hd, window, bq, bk,
                                        dtype):
    key = jax.random.PRNGKey(hash((bh, s, hd)) % 2**31)
    q = rand(key, (bh, s, hd), dtype)
    k = rand(jax.random.fold_in(key, 1), (bhkv, s, hd), dtype)
    v = rand(jax.random.fold_in(key, 2), (bhkv, s, hd), dtype, scale=1.0)
    out = flash_attention_fwd(q, k, v, window=window, block_q=bq,
                              block_k=bk, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, window=window)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=atol, rtol=atol)


def test_flash_attention_first_row_is_v0():
    """Causal: position 0 attends only to itself."""
    q = rand(jax.random.PRNGKey(0), (2, 64, 32), jnp.float32)
    k = rand(jax.random.PRNGKey(1), (2, 64, 32), jnp.float32)
    v = rand(jax.random.PRNGKey(2), (2, 64, 32), jnp.float32)
    out = flash_attention_fwd(q, k, v, block_q=32, block_k=32,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(v[:, 0]),
                               atol=1e-5)


WKV_CASES = [
    # (BH, S, hd, chunk)
    (4, 64, 16, 16),
    (2, 128, 32, 32),
    (8, 128, 64, 64),
    (3, 96, 16, 32),      # S % chunk != 0 handled by chunk=min → 32|96
    (2, 256, 64, 128),
]


@pytest.mark.parametrize("bh,s,hd,chunk", WKV_CASES)
def test_wkv6_matches_oracle(bh, s, hd, chunk):
    key = jax.random.PRNGKey(hash((bh, s, hd)) % 2**31)
    r = rand(key, (bh, s, hd), jnp.float32)
    k = rand(jax.random.fold_in(key, 1), (bh, s, hd), jnp.float32)
    v = rand(jax.random.fold_in(key, 2), (bh, s, hd), jnp.float32)
    # decay in (0, 1) like exp(-exp(w))
    w = jax.nn.sigmoid(rand(jax.random.fold_in(key, 3), (bh, s, hd),
                            jnp.float32, scale=2.0)) * 0.98
    u = rand(jax.random.fold_in(key, 4), (bh, hd), jnp.float32)
    out = wkv6_chunked(r, k, v, w, u, chunk=chunk, interpret=True)
    expect = ref.wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-4, rtol=2e-3)


def test_wkv6_state_carries_across_chunks():
    """A signal planted in chunk 0 must influence outputs in chunk 2+."""
    bh, s, hd = 1, 96, 16
    r = jnp.ones((bh, s, hd), jnp.float32) * 0.1
    k = jnp.zeros((bh, s, hd), jnp.float32).at[0, 0].set(1.0)
    v = jnp.zeros((bh, s, hd), jnp.float32).at[0, 0].set(1.0)
    w = jnp.full((bh, s, hd), 0.99, jnp.float32)
    u = jnp.zeros((bh, hd), jnp.float32)
    out = wkv6_chunked(r, k, v, w, u, chunk=32, interpret=True)
    assert float(jnp.abs(out[0, 80]).max()) > 1e-4, \
        "state did not propagate across chunk boundaries"
    expect = ref.wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5)


def test_model_wkv_scan_matches_kernel():
    """The in-model chunked time scan (ssm.py) and the Pallas kernel
    implement the same recurrence."""
    from repro.models.ssm import wkv_step, chunked_time_scan
    bh, s, hd = 2, 64, 16
    h = 2  # heads per batch entry in the model layout
    b = bh // h
    key = jax.random.PRNGKey(3)
    r = rand(key, (bh, s, hd), jnp.float32)
    k = rand(jax.random.fold_in(key, 1), (bh, s, hd), jnp.float32)
    v = rand(jax.random.fold_in(key, 2), (bh, s, hd), jnp.float32)
    w = jax.nn.sigmoid(rand(jax.random.fold_in(key, 3), (bh, s, hd),
                            jnp.float32)) * 0.98
    u = rand(jax.random.fold_in(key, 4), (h, hd), jnp.float32)

    # model layout: (S, B, H, hd) scanned
    rm = r.reshape(b, h, s, hd).transpose(2, 0, 1, 3)
    km = k.reshape(b, h, s, hd).transpose(2, 0, 1, 3)
    vm = v.reshape(b, h, s, hd).transpose(2, 0, 1, 3)
    wm = w.reshape(b, h, s, hd).transpose(2, 0, 1, 3)
    state0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    _, ys = chunked_time_scan(lambda st, x: wkv_step(st, x, u), state0,
                              (rm, km, vm, wm), chunk=16)
    model_out = ys.transpose(1, 2, 0, 3).reshape(bh, s, hd)

    u_k = jnp.tile(u, (b, 1))
    kern_out = wkv6_chunked(r, k, v, w, u_k, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(model_out), np.asarray(kern_out),
                               atol=2e-5, rtol=1e-4)


# ------------------------------------------------------------ flash decode
from repro.kernels.decode_attention import flash_decode
from repro.models.layers import decode_attention_ref


DECODE_CASES = [
    # (B, Hkv, grp, S, hd, block_s, dtype)
    (2, 2, 4, 256, 64, 64, jnp.float32),      # GQA 4x
    (1, 4, 1, 512, 128, 128, jnp.float32),    # MHA-per-kv, MXU-aligned
    (2, 2, 8, 384, 64, 128, jnp.float32),     # ragged S vs block
    (2, 2, 4, 256, 64, 64, jnp.bfloat16),     # bf16 io
]


@pytest.mark.parametrize("b,hkv,grp,s,hd,bs,dtype", DECODE_CASES)
def test_flash_decode_matches_oracle(b, hkv, grp, s, hd, bs, dtype):
    key = jax.random.PRNGKey(hash((b, s, hd)) % 2**31)
    h = hkv * grp
    q = rand(key, (b, 1, h, hd), dtype)
    kc = rand(jax.random.fold_in(key, 1), (b, s, hkv, hd), dtype)
    vc = rand(jax.random.fold_in(key, 2), (b, s, hkv, hd), dtype, 1.0)
    cache_len = jnp.array([s // 2, s][:b] if b > 1 else [s // 2],
                          jnp.int32)[:b]
    expect = decode_attention_ref(q, kc, vc, cache_len)   # (B,1,H,hd)

    # kernel layout: fold (B, Hkv) and group queries on their kv head
    qg = q[:, 0].reshape(b, hkv, grp, hd).reshape(b * hkv, grp, hd)
    kk = kc.transpose(0, 2, 1, 3).reshape(b * hkv, s, hd)
    vv = vc.transpose(0, 2, 1, 3).reshape(b * hkv, s, hd)
    lens = jnp.repeat(cache_len, hkv)
    out = flash_decode(qg, kk, vv, lens, block_s=bs, interpret=True)
    out = out.reshape(b, hkv, grp, hd).reshape(b, 1, h, hd)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=atol, rtol=atol)


def test_flash_decode_respects_cache_len():
    """Slots beyond cache_len must not influence the output."""
    b, s, hd = 1, 128, 32
    q = rand(jax.random.PRNGKey(0), (b, 4, hd), jnp.float32)
    k = rand(jax.random.PRNGKey(1), (b, s, hd), jnp.float32)
    v = rand(jax.random.PRNGKey(2), (b, s, hd), jnp.float32)
    out1 = flash_decode(q, k, v, jnp.array([64]), block_s=64,
                        interpret=True)
    # poison the masked region: result must be identical
    k2 = k.at[:, 64:].set(99.0)
    v2 = v.at[:, 64:].set(-99.0)
    out2 = flash_decode(q, k2, v2, jnp.array([64]), block_s=64,
                        interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               atol=1e-6)


def test_ops_dispatch_reference_vs_interpret():
    """The jit'd dispatch wrappers agree across impls."""
    from repro.kernels.ops import decode_attention, flash_attention, wkv6
    key = jax.random.PRNGKey(9)
    q = rand(key, (4, 2, 32), jnp.float32)
    k = rand(jax.random.fold_in(key, 1), (4, 64, 32), jnp.float32)
    v = rand(jax.random.fold_in(key, 2), (4, 64, 32), jnp.float32)
    lens = jnp.array([64, 32, 64, 16], jnp.int32)
    a = decode_attention(q, k, v, lens, impl="reference")
    b = decode_attention(q, k, v, lens, impl="pallas_interpret", block_s=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)

    qf = rand(key, (4, 128, 32), jnp.float32)
    a = flash_attention(qf, k, v, impl="reference")
    b = flash_attention(qf, k, v, impl="pallas_interpret", block_q=64,
                        block_k=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=1e-4)

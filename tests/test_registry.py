"""coord/registry edge cases: the event-log membership fold (rejoin),
heartbeat TTL liveness, and per-worker straggler windowing."""

from __future__ import annotations

import pytest

from repro.coord.kvstore import LocalCoordinator
from repro.coord.registry import (ClusterRegistry, fold_members, live_from,
                                  straggler_flags_from)


# ------------------------------------------------- pure fold helpers
def test_fold_members_rejoin_order():
    events = [
        {"ev": "join", "id": "w0", "t": 0.0},
        {"ev": "leave", "id": "w0", "t": 1.0},
        {"ev": "join", "id": "w0", "t": 2.0},   # the rejoin a set
    ]                                           # difference would kill
    assert set(fold_members(events)) == {"w0"}
    assert live_from(events) == {"w0"}


def test_fold_members_leave_wins_in_log_order():
    events = [
        {"ev": "join", "id": "w0", "t": 5.0},   # wall times lie; LOG
        {"ev": "leave", "id": "w0", "t": 1.0},  # order is the truth
    ]
    assert live_from(events) == set()


def test_heartbeat_only_refreshes_registered_workers():
    events = [{"ev": "hb", "id": "ghost", "t": 1.0},
              {"ev": "join", "id": "w0", "t": 1.0},
              {"ev": "leave", "id": "w0", "t": 2.0},
              {"ev": "hb", "id": "w0", "t": 3.0}]
    assert live_from(events) == set()
    assert live_from(events, now=3.0, ttl=10.0) == set()


def test_ttl_liveness_from_heartbeats():
    events = [{"ev": "join", "id": "w0", "t": 0.0},
              {"ev": "join", "id": "w1", "t": 0.0},
              {"ev": "hb", "id": "w0", "t": 5.0}]
    assert live_from(events, now=5.2, ttl=1.0) == {"w0"}     # w1 expired
    assert live_from(events, now=5.2, ttl=None) == {"w0", "w1"}


def test_single_worker_median_not_self_flagged():
    reports = [{"id": "w0", "step": i, "s": 1.0} for i in range(5)]
    assert straggler_flags_from(reports) == {"w0": False}


def test_per_worker_window_keeps_slow_reporters():
    # the old global [-window:] slice: 200 fast reports would evict the
    # slow worker's 3 reports from the sample entirely
    reports = ([{"id": "slow", "step": i, "s": 3.0} for i in range(3)]
               + [{"id": "fast", "step": i, "s": 1.0} for i in range(200)])
    flags = straggler_flags_from(reports, threshold=1.5, window=64)
    assert flags == {"slow": True, "fast": False}


def test_straggler_flag_flips_after_recovery():
    slow = [{"id": "w0", "step": i, "s": 4.0} for i in range(10)]
    fast = [{"id": "w1", "step": i, "s": 1.0} for i in range(10)]
    assert straggler_flags_from(slow + fast, window=64)["w0"] is True
    # w0 recovers: a full window of fast reports displaces the slow ones
    recovered = [{"id": "w0", "step": 10 + i, "s": 1.0} for i in range(64)]
    flags = straggler_flags_from(slow + fast + recovered, window=64)
    assert flags["w0"] is False


# ------------------------------------------- through the coordinator
@pytest.fixture(scope="module")
def registry():
    return ClusterRegistry(LocalCoordinator(seed=7))


def test_registry_rejoin_after_leave(registry):
    registry.register_worker("r0")
    registry.deregister_worker("r0")
    assert "r0" not in registry.live_workers()
    registry.register_worker("r0")
    assert "r0" in registry.live_workers()


def test_registry_heartbeat_ttl_expiry(registry):
    registry.register_worker("h0")
    registry.register_worker("h1")
    loop = registry.coord.cluster.loop
    loop.run_until(loop.now + 2.0)          # both join-times age out
    registry.heartbeat("h0")
    live = registry.live_workers(ttl=1.0)
    assert "h0" in live and "h1" not in live
    assert {"h0", "h1"} <= registry.live_workers()   # no TTL: membership

"""Bounded-uncertainty clock invariants (paper §2.2, §4.3)."""

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fixed-example fallback
    from _hypothesis_stub import given, settings, st

from repro.core.clock import BoundedClock, TimeInterval
from repro.core.prob import PRNG
from repro.core.simulate import EventLoop


def make_clock(max_error=50e-6, seed=0):
    loop = EventLoop()
    return loop, BoundedClock(loop, PRNG(seed), max_error)


@given(st.integers(0, 10_000), st.floats(1e-7, 1e-3))
@settings(max_examples=200, deadline=None)
def test_interval_contains_true_time(seed, max_error):
    loop, clock = make_clock(max_error, seed)
    loop.now = 123.456
    iv = clock.interval_now()
    assert iv.earliest <= loop.now <= iv.latest
    assert iv.latest - iv.earliest <= 2 * max_error + 1e-12


@given(st.integers(0, 2_000), st.floats(0.0, 2.0), st.floats(1e-6, 1e-2))
@settings(max_examples=300, deadline=None)
def test_commit_and_read_gates_are_disjoint(seed, age, max_error):
    """At any true moment, 'provably expired' and 'lease valid' never both
    hold — the Case-2 proof obligation (paper §4.2/§4.3)."""
    loop, clock = make_clock(max_error, seed)
    delta = 1.0
    # an entry stamped at true time 10.0 with its own (different) clock
    loop.now = 10.0
    stamp_clock = BoundedClock(loop, PRNG(seed + 1), max_error)
    t1 = stamp_clock.interval_now()
    loop.now = 10.0 + age
    definitely_old = clock.definitely_older_than(t1, delta)
    valid = clock.lease_valid(t1, delta)
    assert not (definitely_old and valid)
    # and far from the boundary both are decisive
    if age > delta + 4 * max_error:
        assert definitely_old and not valid
    if age < delta - 4 * max_error:
        assert valid and not definitely_old


def test_gate_boundary_behavior():
    loop, clock = make_clock(max_error=1e-4)
    loop.now = 0.0
    t1 = TimeInterval(0.0, 0.0)
    delta = 1.0
    loop.now = 0.5
    assert clock.lease_valid(t1, delta)
    assert not clock.definitely_older_than(t1, delta)
    loop.now = 2.0
    assert not clock.lease_valid(t1, delta)
    assert clock.definitely_older_than(t1, delta)


def test_faulty_clock_breaks_the_guarantee():
    """§4.3: if true time is outside the claimed interval, the disjointness
    argument collapses — this is what the fault injection models."""
    loop = EventLoop()
    clock = BoundedClock(loop, PRNG(0), 1e-6, faulty=True, fault_skew=-5.0)
    loop.now = 10.0
    iv = clock.interval_now()
    assert not (iv.earliest <= loop.now <= iv.latest)

"""The fault library: every perturbation the nemesis engine can apply.

Network faults build on ``Network``'s directional cuts and
:class:`~repro.core.network.MessageFault` rules; clock faults on
``BoundedClock.set_skew`` (honest) and ``faulty`` (lying); process faults
on ``Node.crash``/``Node.restart(wipe_disk=...)``.

Victim selection goes through ``FaultContext.pick(scope)`` and is
resolved at *activation* time, so e.g. ``scope="leader"`` targets
whoever leads when the window opens — and :class:`LeaderNemesis`
re-resolves on every firing, chasing each newly elected leader.
"""

from __future__ import annotations

from typing import Optional

from ..core.network import MessageFault
from .base import Fault, FaultContext


# ---------------------------------------------------------------- partitions
class _PartitionFault(Fault):
    """Shared undo bookkeeping: subclasses cut directed links via
    ``_cut``; ``stop`` heals exactly what was cut."""

    def __init__(self) -> None:
        self._cuts: list[tuple[int, int]] = []

    def _cut(self, ctx: FaultContext, src: int, dst: int) -> None:
        ctx.net.partition_oneway(src, dst)
        self._cuts.append((src, dst))

    def _cut_pair(self, ctx: FaultContext, a: int, b: int) -> None:
        self._cut(ctx, a, b)
        self._cut(ctx, b, a)

    def stop(self, ctx: FaultContext) -> None:
        for src, dst in self._cuts:
            ctx.net.heal_oneway(src, dst)
        self._cuts.clear()


class IsolateLeader(_PartitionFault):
    """Cut the current leader off from everyone. ``direction``:

    * ``both`` — classic symmetric isolation;
    * ``out``  — the leader can hear but not be heard (followers miss
      heartbeats and elect; the deposed leader learns of it);
    * ``in``   — the leader can be heard but hears nothing (followers stay
      quiet, the leader cannot commit: an availability trap).
    """

    def __init__(self, direction: str = "both") -> None:
        super().__init__()
        assert direction in ("both", "in", "out"), direction
        self.direction = direction
        self.name = f"isolate_leader[{direction}]"

    def start(self, ctx: FaultContext) -> None:
        vid = ctx.leader_id()
        for other in ctx.ids():
            if other == vid:
                continue
            if self.direction in ("both", "out"):
                self._cut(ctx, vid, other)
            if self.direction in ("both", "in"):
                self._cut(ctx, other, vid)


class MajorityMinority(_PartitionFault):
    """Split the cluster into two sides; ``leader_in_minority`` puts the
    leader on the losing side (the classic failover-forcing split)."""

    def __init__(self, leader_in_minority: bool = True) -> None:
        super().__init__()
        self.leader_in_minority = leader_in_minority
        side = "minority" if leader_in_minority else "majority"
        self.name = f"majority_minority[leader_in_{side}]"

    def start(self, ctx: FaultContext) -> None:
        if self.leader_in_minority:
            minority = set(ctx.minority(with_leader=True))
        else:
            minority = set(ctx.minority(with_leader=False))
        for a in ctx.ids():
            for b in ctx.ids():
                if a < b and (a in minority) != (b in minority):
                    self._cut_pair(ctx, a, b)


class PartialPartition(_PartitionFault):
    """Cut a single follower-follower link: both endpoints still see the
    rest of the cluster (the Cloudflare-outage topology that traps naive
    Raft implementations in election loops)."""

    name = "partial_partition"

    def start(self, ctx: FaultContext) -> None:
        followers = ctx.followers()
        if len(followers) >= 2:
            self._cut_pair(ctx, followers[0], followers[1])


class OneWayLink(_PartitionFault):
    """Cut exactly one directed link between the two lowest followers."""

    name = "oneway_link"

    def start(self, ctx: FaultContext) -> None:
        followers = ctx.followers()
        if len(followers) >= 2:
            self._cut(ctx, followers[0], followers[1])


# -------------------------------------------------------------- clock faults
class ClockSkew(Fault):
    """Per-node clock skew/drift. Honest by default (bounds widen, safety
    holds, availability degrades); ``lie=True`` makes the clock claim its
    normal tight bounds while actually being off — the §4.3 fault model
    breach that forfeits linearizability."""

    def __init__(self, skew: float, drift_rate: float = 0.0,
                 scope: str = "minority", lie: bool = False) -> None:
        self.skew = skew
        self.drift_rate = drift_rate
        self.scope = scope
        self.lie = lie
        kind = "lying" if lie else "honest"
        self.name = f"clock_skew[{kind},{scope}]"
        self._victims: list[int] = []

    def start(self, ctx: FaultContext) -> None:
        self._victims = ctx.pick(self.scope)
        for nid in self._victims:
            clock = ctx.nodes[nid].clock
            if self.lie:
                clock.faulty = True
                clock.fault_skew = self.skew
            else:
                clock.set_skew(self.skew, self.drift_rate)

    def stop(self, ctx: FaultContext) -> None:
        for nid in self._victims:
            clock = ctx.nodes[nid].clock
            if self.lie:
                clock.faulty = False
                clock.fault_skew = 0.0
            else:
                clock.clear_skew()
        self._victims = []


# ------------------------------------------------------------ process faults
class CrashRestart(Fault):
    """Crash the scope's nodes, restart them ``downtime`` later. With
    ``wipe_disk`` the restart loses persistent state (term/vote/log) —
    beyond Raft's fault model, hence only in unsafe scenarios."""

    def __init__(self, scope: str = "leader", downtime: float = 0.3,
                 wipe_disk: bool = False) -> None:
        self.scope = scope
        self.downtime = downtime
        self.wipe_disk = wipe_disk
        wipe = ",wipe" if wipe_disk else ""
        self.name = f"crash_restart[{scope}{wipe}]"
        self._down: list[int] = []

    def start(self, ctx: FaultContext) -> None:
        for nid in ctx.pick(self.scope):
            node = ctx.nodes[nid]
            if not node.alive:
                continue
            node.crash()
            self._down.append(nid)
            ctx.loop.call_later(
                self.downtime, lambda n=node: self._restart(ctx, n))

    def _restart(self, ctx: FaultContext, node) -> None:
        if not node.alive:
            node.restart(wipe_disk=self.wipe_disk)
            ctx.note(f"restarted node {node.id}"
                     f"{' (disk wiped)' if self.wipe_disk else ''}")
        if node.id in self._down:
            self._down.remove(node.id)

    def stop(self, ctx: FaultContext) -> None:
        # window closes early: bring anything still down back now
        for nid in list(self._down):
            node = ctx.nodes[nid]
            if not node.alive:
                node.restart(wipe_disk=self.wipe_disk)
        self._down.clear()


class LeaderNemesis(Fault):
    """The leader-chasing nemesis: every ``period`` it checks for a leader
    of a term it has not struck yet and crash-restarts it. Because the
    victim is re-resolved per firing, each newly elected leader gets hit
    in turn — the schedule the paper's availability story must survive."""

    def __init__(self, period: float = 0.5, downtime: float = 0.25,
                 wipe_disk: bool = False) -> None:
        self.period = period
        self.downtime = downtime
        self.wipe_disk = wipe_disk
        self.name = f"leader_nemesis[p={period}]"
        self._active = False
        self._last_struck_term = -1

    def start(self, ctx: FaultContext) -> None:
        self._active = True
        self._last_struck_term = -1
        self._tick(ctx)

    def _tick(self, ctx: FaultContext) -> None:
        if not self._active:
            return
        ldr = ctx.leader()
        if ldr is not None and ldr.alive and ldr.is_leader() \
                and ldr.term > self._last_struck_term:
            self._last_struck_term = ldr.term
            ctx.note(f"nemesis strikes leader {ldr.id} (term {ldr.term})")
            ldr.crash()
            ctx.loop.call_later(
                self.downtime,
                lambda n=ldr: n.restart(wipe_disk=self.wipe_disk)
                if not n.alive else None)
        ctx.loop.call_later(self.period, lambda: self._tick(ctx))

    def stop(self, ctx: FaultContext) -> None:
        self._active = False
        for node in ctx.nodes.values():
            if not node.alive:
                node.restart(wipe_disk=self.wipe_disk)


# ------------------------------------------------------------ message faults
class MessageChaos(Fault):
    """Install a :class:`MessageFault` rule for the window: extra delay,
    reorder jitter, probabilistic loss, duplication — globally or on one
    directed link."""

    def __init__(self, extra_delay: float = 0.0, jitter: float = 0.0,
                 drop_prob: float = 0.0, dup_prob: float = 0.0,
                 src: Optional[int] = None, dst: Optional[int] = None,
                 label: str = "") -> None:
        self.rule = MessageFault(extra_delay=extra_delay, jitter=jitter,
                                 drop_prob=drop_prob, dup_prob=dup_prob,
                                 src=src, dst=dst)
        self.name = f"message_chaos[{label}]" if label else "message_chaos"
        self._handle: Optional[int] = None

    def start(self, ctx: FaultContext) -> None:
        self._handle = ctx.net.add_fault(self.rule)

    def stop(self, ctx: FaultContext) -> None:
        if self._handle is not None:
            ctx.net.remove_fault(self._handle)
            self._handle = None


class IoSlowdown(Fault):
    """Extra per-message I/O service time on the scope's nodes (models a
    slow disk / saturated NIC rather than a slow network)."""

    def __init__(self, extra_service_time: float = 200e-6,
                 scope: str = "leader") -> None:
        self.extra = extra_service_time
        self.scope = scope
        self.name = f"io_slowdown[{scope}]"
        self._victims: list[int] = []

    def start(self, ctx: FaultContext) -> None:
        self._victims = ctx.pick(self.scope)
        for nid in self._victims:
            ctx.net.set_io_slowdown(nid, self.extra)

    def stop(self, ctx: FaultContext) -> None:
        for nid in self._victims:
            ctx.net.set_io_slowdown(nid, 0.0)
        self._victims = []

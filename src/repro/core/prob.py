"""Seeded pseudorandom distributions (paper §6.1, ``prob.py``).

All nondeterminism in the simulation flows through one :class:`PRNG`, so a
(seed, params) pair reproduces the identical event sequence.
"""

from __future__ import annotations

import math
import random
from typing import Sequence


class PRNG:
    def __init__(self, seed: int) -> None:
        self._r = random.Random(seed)

    def fork(self, salt: int) -> "PRNG":
        """Derive an independent stream (per node / per subsystem)."""
        return PRNG(self._r.randrange(2**63) ^ (salt * 0x9E3779B97F4A7C15) % 2**63)

    def uniform(self, lo: float, hi: float) -> float:
        return self._r.uniform(lo, hi)

    def randint(self, lo: int, hi: int) -> int:
        return self._r.randint(lo, hi)

    def choice(self, xs: Sequence):
        return self._r.choice(xs)

    def shuffle(self, xs: list) -> None:
        self._r.shuffle(xs)

    def random(self) -> float:
        return self._r.random()

    def exponential(self, mean: float) -> float:
        """Interarrival times of a Poisson process with the given mean gap."""
        return self._r.expovariate(1.0 / mean) if mean > 0 else 0.0

    def lognormvariate(self, mu: float, sigma: float) -> float:
        """Raw lognormal draw from precomputed underlying-normal params —
        the hot-path twin of :meth:`lognormal_mean_var` (same stream)."""
        return self._r.lognormvariate(mu, sigma)

    def lognormal_mean_var(self, mean: float, variance: float) -> float:
        """Lognormal sample parameterized by its own mean/variance.

        The paper (§6.4) uses lognormal network latencies "with variance equal
        to the mean"; we convert (mean, var) to the underlying normal's
        (mu, sigma).
        """
        if mean <= 0:
            return 0.0
        sigma2 = math.log(1.0 + variance / (mean * mean))
        mu = math.log(mean) - sigma2 / 2.0
        return self._r.lognormvariate(mu, math.sqrt(sigma2))


class Zipf:
    """Zipf(a) over {0..n-1} via inverse-CDF table (paper §6.6, a in [0, 2])."""

    def __init__(self, n: int, a: float) -> None:
        weights = [1.0 / (k + 1) ** a for k in range(n)]
        total = sum(weights)
        self.cdf: list[float] = []
        acc = 0.0
        for w in weights:
            acc += w / total
            self.cdf.append(acc)
        self.cdf[-1] = 1.0

    def sample(self, prng: PRNG) -> int:
        u = prng.random()
        # binary search
        lo, hi = 0, len(self.cdf) - 1
        while lo < hi:
            mid = (lo + hi) // 2
            if self.cdf[mid] < u:
                lo = mid + 1
            else:
                hi = mid
        return lo

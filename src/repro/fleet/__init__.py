"""Deterministic training-fleet simulation (the data plane).

N worker actors share the simulated event loop with the Raft replica
set they coordinate through: register/heartbeat in the membership log,
poll the latest checkpoint every step through the configured read
policy, report step times, and elect a chief (fenced through the
replicated fleet log itself) that commits checkpoint manifests. Fault
scenarios compose data-plane chaos (:mod:`repro.fleet.faults`) with the
control-plane nemesis catalogue in one window schedule, and the
post-run lineage checker (:mod:`repro.fleet.lineage`) audits every
restore omnisciently. ``benchmarks/fleet_matrix.py`` sweeps
policy × scenario × seed over :func:`run_fleet`.
"""

from .faults import (CheckpointStorm, ChiefKill, FleetContext, FleetScenario,
                     WorkerCrash, WorkerStraggler)
from .lineage import (FLEET_KEY, LogView, check_lineage, extract_fleet_log)
from .scenarios import (FLEET_SCENARIOS, build_fleet_scenario, fleet_scenario,
                        fleet_scenario_names)
from .sim import Fleet, FleetParams, FleetResult, run_fleet
from .worker import Worker

__all__ = [
    "CheckpointStorm", "ChiefKill", "FleetContext", "FleetScenario",
    "WorkerCrash", "WorkerStraggler",
    "FLEET_KEY", "LogView", "check_lineage", "extract_fleet_log",
    "FLEET_SCENARIOS", "build_fleet_scenario", "fleet_scenario",
    "fleet_scenario_names",
    "Fleet", "FleetParams", "FleetResult", "run_fleet",
    "Worker",
]

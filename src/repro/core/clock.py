"""Bounded-uncertainty clocks (paper §2.2) and drift-bounded timers (§5.3).

``intervalNow()`` returns ``[earliest, latest]`` guaranteed to contain true
time for at least one moment during the call. The simulation knows true time
(the event loop clock) and perturbs it by per-call bounded errors, modeling
AWS TimeSync / clock-bound style interval clocks (<= ``max_clock_error``).

The two LeaseGuard age checks (paper §4.3):

* a node **knows** ``t1`` is *more than Δ old* iff
  ``t1.latest + Δ < intervalNow().earliest``    (commit gate — aggressive side)
* a lease holder may read only while its entry is **not possibly** more than
  Δ old: ``intervalNow().latest <= t1.latest + Δ``  (read gate — conservative
  side)

At any true moment at most one of the two can hold (earliest <= T <= latest),
which is exactly the disjointness the Case-2 proof needs.
"""

from __future__ import annotations

from dataclasses import dataclass

from .prob import PRNG
from .simulate import EventLoop


@dataclass(frozen=True, slots=True)
class TimeInterval:
    earliest: float
    latest: float

    def __post_init__(self) -> None:
        assert self.earliest <= self.latest


class BoundedClock:
    """Per-node interval clock with bounded, randomized uncertainty.

    Two distinct fault models (both driven by ``repro.faults``):

    * **honest skew/drift** (``set_skew``): the oscillator runs fast or
      slow, but the clock daemon *knows* it (as AWS TimeSync / clock-bound
      do) and widens the reported interval so it still contains true time.
      Safety is preserved by construction; the cost is availability — wider
      intervals make both LeaseGuard age checks more conservative.
    * **lying clock** (``faulty``/``fault_skew``): the *claimed* bounds are
      wrong — true time escapes the interval. This is the paper's §4.3
      caveat (linearizability is forfeit) and is used by adversarial tests
      to prove the checker catches the resulting stale reads.
    """

    __slots__ = ("loop", "prng", "max_error", "faulty", "fault_skew",
                 "skew", "drift_rate", "_drift_ref")

    def __init__(self, loop: EventLoop, prng: PRNG, max_error: float,
                 faulty: bool = False, fault_skew: float = 0.0) -> None:
        self.loop = loop
        self.prng = prng
        self.max_error = max_error
        self.faulty = faulty
        self.fault_skew = fault_skew
        # honest skew: offset + linear drift from the anchor time
        self.skew = 0.0
        self.drift_rate = 0.0
        self._drift_ref = 0.0

    def set_skew(self, skew: float, drift_rate: float = 0.0) -> None:
        """Install an honest offset (seconds) and drift (seconds/second),
        anchored at the current simulated time."""
        self.skew = skew
        self.drift_rate = drift_rate
        self._drift_ref = self.loop.now

    def clear_skew(self) -> None:
        self.skew = 0.0
        self.drift_rate = 0.0

    def _skew_now(self) -> float:
        s = self.skew
        if self.drift_rate:
            s += self.drift_rate * (self.loop.now - self._drift_ref)
        return s

    def interval_now(self) -> TimeInterval:
        t = self.loop.now
        if self.faulty:
            t = t + self.fault_skew  # true time now OUTSIDE claimed bounds
        lo = self.prng.uniform(0.0, self.max_error)
        hi = self.prng.uniform(0.0, self.max_error)
        s = self._skew_now()
        if s == 0.0:
            return TimeInterval(t - lo, t + hi)
        perceived = t + s
        # honest: report bounds wide enough to cover both the perceived and
        # the reference time, so true time stays inside the interval
        return TimeInterval(min(t, perceived) - lo, max(t, perceived) + hi)

    # -- the two asymmetric age checks ------------------------------------
    def definitely_older_than(self, t1: TimeInterval, delta: float) -> bool:
        """Commit gate: provably more than ``delta`` old."""
        return t1.latest + delta < self.interval_now().earliest

    def possibly_older_than(self, t1: TimeInterval, delta: float) -> bool:
        """Read gate: NOT safe to read iff possibly more than ``delta`` old."""
        return self.interval_now().latest > t1.latest + delta

    def lease_valid(self, t1: TimeInterval, delta: float) -> bool:
        return not self.possibly_older_than(t1, delta)

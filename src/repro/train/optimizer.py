"""Optimizers: AdamW (fp32 states) and Adafactor (factored second moment,
for archs whose Adam states exceed per-device HBM), with global-norm
clipping, warmup+cosine LR, and an optional int8 gradient-compression
stage with error feedback (the distributed-optimization trick: on real
pods the quantized tensor is what crosses the DP axis; here the
quantize/dequantize + error-feedback dynamics are exact)."""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"              # adamw | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    compress: str = "none"           # none | int8_ef


def lr_schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


# ------------------------------------------------------------ compression
def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads_int8_ef(grads, ef):
    """Quantize each leaf to int8 with error feedback. Returns
    (dequantized grads, new error buffers)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = quantize_int8(gf)
        deq = q.astype(jnp.float32) * scale
        return deq, gf - deq
    out = jax.tree.map(one, grads, ef)
    deq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return deq, new_ef


# ------------------------------------------------------------------ adamw
def adamw_init(params, cfg: OptConfig):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {"m": jax.tree.map(zeros, params),
             "v": jax.tree.map(zeros, params)}
    if cfg.compress == "int8_ef":
        state["ef"] = jax.tree.map(zeros, params)
    return state


def adamw_update(grads, state, params, cfg: OptConfig, step):
    if cfg.compress == "int8_ef":
        grads, state["ef"] = compress_grads_int8_ef(grads, state["ef"])
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** (step + 1.0)
    bc2 = 1 - b2 ** (step + 1.0)

    def one(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if p.ndim >= 2:
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        return m, v, (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

    out = jax.tree.map(one, grads, state["m"], state["v"], params)
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    new_state = dict(state)
    new_state["m"], new_state["v"] = pick(0), pick(1)
    return pick(2), new_state


# -------------------------------------------------------------- adafactor
def adafactor_init(params, cfg: OptConfig):
    def one(p):
        if p.ndim >= 2:
            return {"row": jnp.zeros(p.shape[:-1], jnp.float32),
                    "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    state = {"f": jax.tree.map(one, params,
                               is_leaf=lambda x: isinstance(x, jnp.ndarray))}
    if cfg.compress == "int8_ef":
        state["ef"] = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                   params)
    return state


def adafactor_update(grads, state, params, cfg: OptConfig, step):
    if cfg.compress == "int8_ef":
        grads, state["ef"] = compress_grads_int8_ef(grads, state["ef"])
    lr = lr_schedule(cfg, step)
    decay = 1.0 - (step + 1.0) ** -0.8

    def one(g, f, p):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        if p.ndim >= 2:
            row = decay * f["row"] + (1 - decay) * jnp.mean(g2, axis=-1)
            col = decay * f["col"] + (1 - decay) * jnp.mean(g2, axis=-2)
            denom = jnp.sqrt(row[..., None] * col[..., None, :]
                             / (jnp.mean(row, axis=-1, keepdims=True)[..., None]
                                + 1e-30)) + 1e-30
            upd = g / denom
            nf = {"row": row, "col": col}
        else:
            v = decay * f["v"] + (1 - decay) * g2
            upd = g / (jnp.sqrt(v) + 1e-30)
            nf = {"v": v}
        # update clipping (Adafactor RMS trick)
        rms = jnp.sqrt(jnp.mean(upd * upd) + 1e-30)
        upd = upd / jnp.maximum(1.0, rms)
        if p.ndim >= 2:
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        return nf, (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

    is_f = lambda x: isinstance(x, dict) and ("row" in x or "v" in x)
    out = jax.tree.map(one, grads, state["f"], params,
                       is_leaf=lambda x: is_f(x))
    # out mirrors params-structure with (nf, new_p) tuples at leaves
    new_f = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_p = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_state = dict(state)
    new_state["f"] = new_f
    return new_p, new_state


# ------------------------------------------------------------------ public
def init_opt_state(params, cfg: OptConfig):
    if cfg.name == "adafactor":
        return adafactor_init(params, cfg)
    return adamw_init(params, cfg)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def apply_updates(grads, opt_state, params, cfg: OptConfig, step):
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    if cfg.name == "adafactor":
        new_p, new_s = adafactor_update(grads, opt_state, params, cfg, step)
    else:
        new_p, new_s = adamw_update(grads, opt_state, params, cfg, step)
    return new_p, new_s, gnorm

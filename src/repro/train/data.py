"""Deterministic synthetic data pipeline.

Produces a reproducible token stream keyed by (seed, step): restarts from
a checkpoint regenerate identical batches, which the resume test relies
on. In a multi-host deployment each host materializes only its
``process_index`` slice of the global batch (the standard
jax.make_array_from_process_local_data pattern); on this single-host CPU
container the slice is the whole batch.

The "language" is a mixture of repeated n-grams + noise so the loss has
learnable structure (examples/train_lm.py shows it dropping).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ShapeConfig


@dataclass
class DataConfig:
    seed: int = 0
    ngram: int = 8          # learnable structure period


def synth_batch(cfg: ArchConfig, shape: ShapeConfig, step: int,
                data_cfg: DataConfig = DataConfig()) -> dict:
    """Global batch for ``step`` (numpy, host-resident)."""
    rng = np.random.default_rng(
        np.uint64(data_cfg.seed * 1_000_003 + step * 7919))
    b, s = shape.global_batch, shape.seq_len
    v = cfg.vocab_size
    # structured stream: each row repeats a random n-gram with noise
    base = rng.integers(0, v, size=(b, data_cfg.ngram), dtype=np.int64)
    reps = int(np.ceil((s + 1) / data_cfg.ngram))
    seq = np.tile(base, (1, reps))[:, : s + 1]
    noise = rng.random((b, s + 1)) < 0.1
    seq = np.where(noise, rng.integers(0, v, size=(b, s + 1)), seq)
    batch = {"labels": seq[:, 1:].astype(np.int32)}
    if cfg.embedding_stub:
        # frontend stub: precomputed patch/frame embeddings
        emb = rng.standard_normal((b, s, cfg.d_model)).astype(np.float32) * 0.02
        batch["embeds"] = emb
    else:
        batch["tokens"] = seq[:, :-1].astype(np.int32)
    return batch


class DataIterator:
    """Stateful iterator with checkpointable position."""

    def __init__(self, cfg: ArchConfig, shape: ShapeConfig,
                 data_cfg: DataConfig = DataConfig(), start_step: int = 0):
        self.cfg = cfg
        self.shape = shape
        self.data_cfg = data_cfg
        self.step = start_step

    def __next__(self) -> dict:
        batch = synth_batch(self.cfg, self.shape, self.step, self.data_cfg)
        self.step += 1
        return batch

    def state(self) -> dict:
        return {"step": self.step, "seed": self.data_cfg.seed}

    @classmethod
    def from_state(cls, cfg, shape, state: dict) -> "DataIterator":
        return cls(cfg, shape, DataConfig(seed=state["seed"]),
                   start_step=state["step"])

"""Training step: microbatched gradient accumulation, clipping, optimizer
update. Pure function of (state, batch) — jit/pjit-able with shardings."""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import forward_train, init_params
from .optimizer import OptConfig, apply_updates, init_opt_state


def init_train_state(key: jax.Array, cfg: ArchConfig,
                     opt_cfg: OptConfig) -> dict:
    params = init_params(key, cfg)
    return {
        "params": params,
        "opt": init_opt_state(params, opt_cfg),
        "step": jnp.zeros((), jnp.int32),
    }


def _split_microbatches(batch: dict, accum: int) -> dict:
    def reshape(x):
        b = x.shape[0]
        assert b % accum == 0, f"batch {b} not divisible by accum {accum}"
        return x.reshape(accum, b // accum, *x.shape[1:])
    return jax.tree.map(reshape, batch)


def train_step(state: dict, batch: dict, cfg: ArchConfig,
               opt_cfg: OptConfig) -> tuple[dict, dict]:
    """One optimizer step over a global batch (with grad accumulation)."""
    params = state["params"]
    accum = max(1, cfg.grad_accum)

    loss_fn = lambda p, mb: forward_train(p, cfg, mb)

    if accum == 1:
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
    else:
        micro = _split_microbatches(batch, accum)

        def acc_fn(carry, mb):
            loss_sum, grads = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            grads = jax.tree.map(jnp.add, grads, g)
            return (loss_sum + l, grads), None

        zero_grads = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads), _ = jax.lax.scan(
            acc_fn, (jnp.zeros((), jnp.float32), zero_grads), micro)
        loss = loss_sum / accum
        grads = jax.tree.map(lambda g: g / accum, grads)

    new_params, new_opt, gnorm = apply_updates(
        grads, state["opt"], params, opt_cfg, state["step"])
    new_state = {"params": new_params, "opt": new_opt,
                 "step": state["step"] + 1}
    metrics = {"loss": loss, "grad_norm": gnorm}
    return new_state, metrics


def make_train_step(cfg: ArchConfig, opt_cfg: OptConfig):
    return partial(train_step, cfg=cfg, opt_cfg=opt_cfg)

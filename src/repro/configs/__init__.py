"""Model-zoo registry: ``--arch <id>`` resolves here."""

from .base import (ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K,
                   ArchConfig, ShapeConfig, shape_applicable)
from .arctic_480b import CONFIG as ARCTIC_480B
from .h2o_danube_1_8b import CONFIG as H2O_DANUBE_1_8B
from .hymba_1_5b import CONFIG as HYMBA_1_5B
from .moonshot_v1_16b_a3b import CONFIG as MOONSHOT_V1_16B_A3B
from .musicgen_large import CONFIG as MUSICGEN_LARGE
from .phi3_mini_3_8b import CONFIG as PHI3_MINI_3_8B
from .pixtral_12b import CONFIG as PIXTRAL_12B
from .qwen2_5_3b import CONFIG as QWEN2_5_3B
from .qwen3_8b import CONFIG as QWEN3_8B
from .rwkv6_3b import CONFIG as RWKV6_3B

ARCHS: dict[str, ArchConfig] = {
    c.name: c for c in [
        MOONSHOT_V1_16B_A3B, ARCTIC_480B, PIXTRAL_12B, QWEN3_8B,
        PHI3_MINI_3_8B, QWEN2_5_3B, H2O_DANUBE_1_8B, RWKV6_3B, HYMBA_1_5B,
        MUSICGEN_LARGE,
    ]
}

SHAPES: dict[str, ShapeConfig] = {s.name: s for s in ALL_SHAPES}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


__all__ = ["ARCHS", "SHAPES", "ArchConfig", "ShapeConfig", "get_arch",
           "get_shape", "shape_applicable", "ALL_SHAPES", "TRAIN_4K",
           "PREFILL_32K", "DECODE_32K", "LONG_500K"]

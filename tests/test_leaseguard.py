"""LeaseGuard protocol behaviour (paper §3, §5): commit gate, deferred
commit writes, inherited lease reads, limbo region, lease upkeep,
Ongaro-lease and quorum-read baselines, and the key stale-read safety
property."""

import pytest

from repro.core import RaftParams, ReadMode, SimParams, build_cluster

DELTA = 2.0


def make(**kw):
    raft_kw = dict(lease_duration=DELTA, election_timeout=0.5)
    raft_kw.update(kw)
    return build_cluster(RaftParams(**raft_kw), SimParams())


def settle(c, dt):
    c.loop.run_until(c.loop.now + dt)


def write(c, node, key, value):
    return c.loop.run_until_complete(
        c.loop.create_task(node.client_write(key, value)))


def read(c, node, key):
    return c.loop.run_until_complete(
        c.loop.create_task(node.client_read(key)))


def fail_leader(c):
    """Crash the leader; return (old_leader, new_leader, crash_time)."""
    ldr = c.wait_for_leader()
    t = c.loop.now
    ldr.crash()
    deadline = t + 5.0
    while c.loop.now < deadline:
        settle(c, 0.05)
        for n in c.nodes.values():
            if n.is_leader() and n is not ldr:
                return ldr, n, t
    raise RuntimeError("no new leader")


# ------------------------------------------------------------- commit gate
def test_commit_gate_blocks_then_opens():
    c = make()
    ldr = c.wait_for_leader()
    assert write(c, ldr, "x", 1).ok
    last_entry_time = c.loop.now
    old, new, t_crash = fail_leader(c)
    # inside the old lease window: the new leader must not commit
    assert c.loop.now < last_entry_time + DELTA
    assert new._commit_gate_blocked()
    ci_before = new.commit_index
    settle(c, 0.2)
    assert new.commit_index == ci_before
    # after Δ the gate opens and the no-op commits
    c.loop.run_until(last_entry_time + DELTA + 0.3)
    assert not new._commit_gate_blocked()
    assert new.commit_index > ci_before
    assert new.log[new.commit_index].term == new.term


def test_deferred_commit_write_acked_after_gate_opens():
    c = make()
    ldr = c.wait_for_leader()
    assert write(c, ldr, "x", 1).ok
    t_last = c.loop.now
    old, new, _ = fail_leader(c)
    assert new._commit_gate_blocked()
    t0 = c.loop.now
    res = write(c, new, "y", 2)     # accepted now, acked at lease expiry
    assert res.ok
    assert c.loop.now >= t_last + DELTA - 2 * new.clock.max_error - 0.01
    settle(c, 0.5)
    for n in c.nodes.values():
        if n.alive:
            assert n.data.get("y") == [2]


def test_unoptimized_log_lease_refuses_writes_during_old_lease():
    c = make(defer_commit_writes=False, inherited_lease_reads=False)
    ldr = c.wait_for_leader()
    assert write(c, ldr, "x", 1).ok
    t_last = c.loop.now
    old, new, _ = fail_leader(c)
    if c.loop.now < t_last + DELTA - 0.3:   # still inside the lease window
        res = write(c, new, "y", 2)
        assert not res.ok and res.error == "no_lease"
        res = read(c, new, "x")
        assert not res.ok and res.error == "no_lease"
    # after expiry everything flows again
    c.loop.run_until(t_last + DELTA + 0.5)
    assert write(c, new, "y", 3).ok
    assert read(c, new, "y").value == [3]


# ---------------------------------------------------- inherited lease reads
def test_inherited_lease_reads_and_limbo_region():
    c = make()
    ldr = c.wait_for_leader()
    assert write(c, ldr, "safe", 1).ok
    assert write(c, ldr, "safe", 2).ok
    settle(c, 0.3)   # followers learn commitIndex covering "safe"
    ldr.freeze_commits()
    for v in (10, 11, 12):
        assert write(c, ldr, "limbo_key", v).ok   # committed, acked, hidden
    t_last = c.loop.now
    old, new, _ = fail_leader(c)
    assert c.loop.now < t_last + DELTA, "election must finish inside lease"
    assert new._commit_gate_blocked()
    assert "limbo_key" in new.limbo_keys
    # unaffected key: consistent read with zero communication
    res = read(c, new, "safe")
    assert res.ok and res.value == [1, 2]
    # affected key: rejected (returning [] or [10,11] would be stale/ahead)
    res = read(c, new, "limbo_key")
    assert not res.ok and res.error == "limbo"
    # once the gate opens and the no-op commits, limbo clears
    c.loop.run_until(t_last + DELTA + 0.5)
    res = read(c, new, "limbo_key")
    assert res.ok and res.value == [10, 11, 12]   # old leader's acked writes


def test_without_inherited_reads_new_leader_rejects_all_reads():
    c = make(inherited_lease_reads=False)
    ldr = c.wait_for_leader()
    assert write(c, ldr, "x", 1).ok
    t_last = c.loop.now
    old, new, _ = fail_leader(c)
    if c.loop.now < t_last + DELTA - 0.3:
        res = read(c, new, "x")
        assert not res.ok and res.error == "no_lease"


# ------------------------------------------------------------ stale reads
def test_partitioned_old_leader_loses_lease_and_refuses_reads():
    """THE safety property: a deposed leader cannot serve stale reads
    after its lease expires, even though it still thinks it leads."""
    c = make()
    ldr = c.wait_for_leader()
    assert write(c, ldr, "x", 1).ok
    others = [n for n in c.nodes.values() if n is not ldr]
    for o in others:
        c.net.partition(ldr.id, o.id)
    t_part = c.loop.now
    settle(c, 2.5)   # new leader elected; old lease expired
    new = next(n for n in others if n.is_leader())
    c.loop.run_until(t_part + DELTA + 1.0)
    assert write(c, new, "x", 2).ok
    # old leader: still believes it leads, but its newest committed entry is
    # stale, so the read gate fails — no stale [1] is ever returned.
    assert ldr.state == "leader"
    res = read(c, ldr, "x")
    assert not res.ok and res.error == "no_lease"


def test_gray_failure_leader_cannot_keep_lease():
    """§1: only a leader that can majority-replicate entries keeps a lease.
    A leader that cannot reach a majority (gray failure) loses it after Δ."""
    c = make()
    ldr = c.wait_for_leader()
    assert write(c, ldr, "x", 1).ok
    assert read(c, ldr, "x").ok
    for o in c.nodes.values():
        if o is not ldr:
            c.net.partition(ldr.id, o.id)
    settle(c, DELTA + 4 * ldr.clock.max_error + 0.1)
    res = read(c, ldr, "x")
    assert not res.ok and res.error == "no_lease"


# ------------------------------------------------------------- lease upkeep
def test_lease_maintained_by_noops_when_idle():
    c = make()
    ldr = c.wait_for_leader()
    assert write(c, ldr, "x", 1).ok
    settle(c, 3 * DELTA)   # idle far beyond Δ: maintenance no-ops keep it
    res = read(c, ldr, "x")
    assert res.ok and res.value == [1]


def test_lease_expires_without_maintenance():
    c = make(lease_maintenance=False)
    ldr = c.wait_for_leader()
    assert write(c, ldr, "x", 1).ok
    settle(c, DELTA + 0.2)
    res = read(c, ldr, "x")
    assert not res.ok and res.error == "no_lease"


def test_end_lease_handover_lets_next_leader_commit_immediately():
    """Planned failover (§5.1): relinquish, crash, next leader skips Δ."""
    c = make()
    ldr = c.wait_for_leader()
    assert write(c, ldr, "x", 1).ok
    ldr.relinquish_lease()
    settle(c, 0.3)          # end-lease entry replicates
    old, new, t_crash = fail_leader(c)
    assert not new._commit_gate_blocked()
    res = write(c, new, "y", 2)
    assert res.ok and c.loop.now < t_crash + 2.0 + DELTA / 2


# ------------------------------------------------------------- baselines
def test_ongaro_lease_serves_reads_and_lapses_when_partitioned():
    c = make(read_mode=ReadMode.ONGARO_LEASE, election_timeout=0.5)
    ldr = c.wait_for_leader()
    assert write(c, ldr, "x", 1).ok
    settle(c, 0.2)
    assert read(c, ldr, "x").ok
    for o in c.nodes.values():
        if o is not ldr:
            c.net.partition(ldr.id, o.id)
    settle(c, 0.6)   # > ET: majority of s_i stale
    res = read(c, ldr, "x")
    assert not res.ok and res.error == "no_lease"


def test_quorum_read_fails_on_minority_partition():
    c = make(read_mode=ReadMode.QUORUM)
    ldr = c.wait_for_leader()
    assert write(c, ldr, "x", 1).ok
    assert read(c, ldr, "x").ok
    for o in c.nodes.values():
        if o is not ldr:
            c.net.partition(ldr.id, o.id)
    res = read(c, ldr, "x")
    assert not res.ok


def test_leaseguard_read_zero_roundtrips():
    """The headline: consistent reads with zero network messages."""
    c = make()
    ldr = c.wait_for_leader()
    assert write(c, ldr, "x", 1).ok
    settle(c, 0.1)
    sent_before = c.net.messages_sent
    t0 = c.loop.now
    res = read(c, ldr, "x")
    assert res.ok and res.value == [1]
    assert c.loop.now == t0                      # zero latency
    assert c.net.messages_sent == sent_before    # zero messages


def test_quorum_read_costs_a_roundtrip():
    c = make(read_mode=ReadMode.QUORUM)
    ldr = c.wait_for_leader()
    assert write(c, ldr, "x", 1).ok
    t0 = c.loop.now
    res = read(c, ldr, "x")
    assert res.ok
    assert c.loop.now > t0        # at least one network roundtrip

"""In-process coordination service: a LeaseGuard Raft replica set driven
by a crank adapter.

The deterministic simulator (repro.core) models time explicitly; the
trainer lives in wall-clock time. The adapter bridges them: each client
call cranks the simulated event loop forward until the operation's future
resolves (or a simulated timeout passes). One simulated replica set =
one coordination service; fault injection (crash_leader, partition) is
exposed for tests, examples, and failover drills.
"""

from __future__ import annotations

import json
from typing import Any, Optional, Union

from ..consistency import resolve_read_mode
from ..core import (Cluster, RaftParams, ReadMode, SimParams, build_cluster)
from ..core.prob import PRNG
from ..core.raft import Node, ReadResult, WriteResult
from ..core.simulate import TimeoutError_, wait_for


class CoordinatorError(RuntimeError):
    pass


class CoordClient:
    """Event-loop-native client path: awaitable KV operations for actors
    that live *on* the simulated loop (the fleet simulator's training
    workers), beside the crank-based :class:`LocalCoordinator` for
    wall-clock callers. Any number of clients can have operations in
    flight concurrently; values share the coordinator's JSON encoding so
    both paths read each other's keys.

    Operations retry across leader failovers until an op deadline, then
    report failure instead of raising — a worker that cannot reach the
    control plane keeps training (the paper's point: polls are advisory,
    not on the critical path). ``append`` stops retrying the moment an
    attempt is *ambiguous* (an entry was appended but not confirmed)
    unless the record is idempotent; non-idempotent callers confirm by
    reading back, which is how the fleet chief avoids duplicate manifests.

    ``read_any_fraction`` routes that fraction of reads to a random live
    non-leader replica (same idiom as the workload's
    ``follower_read_fraction``) — used to model clients of the
    ``inconsistent`` policy actually hitting stale replicas.
    """

    def __init__(self, cluster: Cluster, prng: Optional[PRNG] = None,
                 op_timeout: float = 0.5, retry_delay: float = 0.05,
                 read_any_fraction: float = 0.0) -> None:
        self.cluster = cluster
        self.prng = prng
        self.op_timeout = op_timeout
        self.retry_delay = retry_delay
        self.read_any_fraction = read_any_fraction
        self.appends_ok = 0
        self.appends_failed = 0
        self.reads_ok = 0
        self.reads_failed = 0
        self.retries = 0

    @property
    def loop(self):
        return self.cluster.loop

    @staticmethod
    def decode(raw: list) -> list:
        return [json.loads(v) for v in raw]

    def _leader_node(self) -> Optional[Node]:
        lid = self.cluster.directory.leader_id
        if lid is None:
            return None
        node = self.cluster.nodes.get(lid)
        if node is None or not node.alive:
            return None
        return node

    def _read_target(self) -> Optional[Node]:
        leader = self._leader_node()
        frac = self.read_any_fraction
        if frac <= 0.0 or self.prng is None or self.prng.random() >= frac:
            return leader
        others = [n for _, n in sorted(self.cluster.nodes.items())
                  if n.alive and n is not leader]
        if not others:
            return leader
        return others[self.prng.randint(0, len(others) - 1)]

    async def append(self, key: str, value: Any, idempotent: bool = False,
                     timeout: Optional[float] = None) -> WriteResult:
        """Replicated append; returns the raft :class:`WriteResult` (the
        caller may hold ``.entry`` — its ``execution_ts`` resolves
        ambiguous outcomes omnisciently, as the workload checker does).
        Retries safe failures (nothing appended) until the deadline;
        ambiguous failures retry only when ``idempotent=True``."""
        payload = json.dumps(value)
        deadline = self.loop.now + (self.op_timeout if timeout is None
                                    else timeout)
        last = WriteResult(False, "unavailable")
        while True:
            node = self._leader_node()
            if node is not None:
                try:
                    last = await wait_for(
                        self.loop.create_task(node.client_write(key, payload)),
                        max(1e-9, deadline - self.loop.now))
                except TimeoutError_:
                    # The in-flight write may still commit; it is ambiguous
                    # but we no longer hold its entry — callers confirm by
                    # reading back.
                    last = WriteResult(False, "client_timeout")
                if last.ok:
                    self.appends_ok += 1
                    return last
                ambiguous = last.entry is not None or last.error == "client_timeout"
                if ambiguous and not idempotent:
                    self.appends_failed += 1
                    return last
            if self.loop.now >= deadline:
                self.appends_failed += 1
                return last
            self.retries += 1
            await self.loop.sleep(self.retry_delay)

    async def read_raw(self, key: str,
                       timeout: Optional[float] = None) -> ReadResult:
        """Read via the configured policy; ``.value`` is the raw (encoded)
        list — ``decode()`` it, or scan it lazily from the tail."""
        deadline = self.loop.now + (self.op_timeout if timeout is None
                                    else timeout)
        while True:
            node = self._read_target()
            if node is not None:
                try:
                    res = await wait_for(
                        self.loop.create_task(node.client_read(key)),
                        max(1e-9, deadline - self.loop.now))
                except TimeoutError_:
                    res = ReadResult(False, error="client_timeout")
                if res.ok:
                    self.reads_ok += 1
                    return res
            if self.loop.now >= deadline:
                self.reads_failed += 1
                return ReadResult(False, error="unavailable")
            self.retries += 1
            await self.loop.sleep(self.retry_delay)

    async def read_list(self, key: str,
                        timeout: Optional[float] = None) -> Optional[list]:
        """Decoded read, or None when the control plane is unavailable."""
        res = await self.read_raw(key, timeout=timeout)
        if not res.ok:
            return None
        return self.decode(res.value)

    def stats(self) -> dict:
        return {"appends_ok": self.appends_ok,
                "appends_failed": self.appends_failed,
                "reads_ok": self.reads_ok,
                "reads_failed": self.reads_failed,
                "retries": self.retries}


class LocalCoordinator:
    """Replicated, linearizable KV (append-only lists per key) with
    LeaseGuard zero-roundtrip reads by default; any policy from the
    ``repro.consistency`` registry can be selected by enum or name."""

    def __init__(self, n_nodes: int = 3, seed: int = 0,
                 read_mode: Union[ReadMode, str] = ReadMode.LEASEGUARD,
                 lease_duration: float = 1.0) -> None:
        self.read_mode = resolve_read_mode(read_mode)
        raft = RaftParams(n_nodes=n_nodes, read_mode=self.read_mode,
                          election_timeout=0.5, heartbeat_interval=0.05,
                          lease_duration=lease_duration)
        sim = SimParams(seed=seed)
        self.cluster: Cluster = build_cluster(raft, sim)
        self.cluster.wait_for_leader()
        self.reads = 0
        self.read_messages = 0

    # -- crank ----------------------------------------------------------
    def _run(self, coro, max_sim_time: float = 30.0):
        loop = self.cluster.loop
        task = loop.create_task(coro)
        deadline = loop.now + max_sim_time
        while not task.done() and loop.now < deadline:
            loop.run_until(loop.now + 0.01)
        if not task.done():
            raise CoordinatorError("coordinator operation timed out")
        return task.result()

    def _leader(self):
        ldr = self.cluster.leader()
        if ldr is None or not ldr.alive:
            # crank until a leader exists (failover in progress)
            self.cluster.wait_for_leader()
            ldr = self.cluster.leader()
        if ldr is None:
            raise CoordinatorError("no leader")
        return ldr

    # -- public KV API ----------------------------------------------------
    def append(self, key: str, value: Any, retries: int = 5) -> None:
        """Linearizable durable write (committed through the Raft log)."""
        payload = json.dumps(value)
        for _ in range(retries):
            ldr = self._leader()
            res = self._run(ldr.client_write(key, payload))
            if res.ok:
                return
            # not_leader / no_lease / timeout: crank forward and retry
            self.cluster.loop.run_until(self.cluster.loop.now + 0.3)
        raise CoordinatorError(f"write failed after {retries} retries")

    def read_list(self, key: str, retries: int = 5) -> list:
        """Linearizable read — zero network roundtrips under LeaseGuard."""
        for _ in range(retries):
            ldr = self._leader()
            before = self.cluster.net.messages_sent
            res = self._run(ldr.client_read(key))
            if res.ok:
                self.reads += 1
                self.read_messages += self.cluster.net.messages_sent - before
                return [json.loads(v) for v in res.value]
            self.cluster.loop.run_until(self.cluster.loop.now + 0.3)
        raise CoordinatorError(f"read failed after {retries} retries")

    def read_latest(self, key: str) -> Optional[Any]:
        xs = self.read_list(key)
        return xs[-1] if xs else None

    # -- elastic scaling (paper §4.4 single-node reconfiguration) ---------
    def add_node(self, wait_for_promotion: bool = True,
                 max_sim_time: float = 30.0) -> int:
        """Add one fresh replica the safe way: it joins as a non-voting
        learner (receives and applies the log, counts toward nothing),
        and the leader promotes it to voter via an ordinary CONFIG entry
        once its match index covers the commit index."""
        new_id = max(self.cluster.nodes) + 1
        ldr = self._leader()
        self.cluster.spawn_node(new_id, ldr.p, learner=True)
        res = self._run(ldr.change_membership(
            set(ldr.config), learners=set(ldr.learners) | {new_id}))
        if not res.ok:
            raise CoordinatorError(f"add_node failed: {res.error}")
        if wait_for_promotion:
            loop = self.cluster.loop
            deadline = loop.now + max_sim_time
            while loop.now < deadline:
                ldr = self._leader()
                if new_id in ldr.config:
                    return new_id
                loop.run_until(loop.now + 0.05)
            raise CoordinatorError(f"node {new_id} was never promoted")
        return new_id

    def remove_node(self, node_id: int, retries: int = 5) -> None:
        """Remove ANY replica, the current leader included: removing the
        leader does a planned handover first (§5.1 end-lease, then step
        aside), waits for the successor, and retries the removal there."""
        for _ in range(retries):
            ldr = self._leader()
            if node_id not in ldr.config and node_id not in ldr.learners:
                return                          # already out
            if node_id == ldr.id:
                self.relinquish_leadership()    # handover, then retry below
                continue
            res = self._run(ldr.change_membership(
                set(ldr.config) - {node_id},
                learners=set(ldr.learners) - {node_id}))
            if res.ok:
                return
            self.cluster.loop.run_until(self.cluster.loop.now + 0.3)
        raise CoordinatorError(f"remove_node({node_id}) failed "
                               f"after {retries} retries")

    # legacy names for the same operations
    def scale_up(self) -> int:
        return self.add_node()

    def scale_down(self, node_id: int) -> None:
        self.remove_node(node_id)

    # -- fault injection ---------------------------------------------------
    def crash_leader(self) -> int:
        ldr = self._leader()
        ldr.crash()
        return ldr.id

    def restart_node(self, node_id: int) -> None:
        self.cluster.nodes[node_id].restart()

    def relinquish_leadership(self) -> None:
        """Planned handover (paper §5.1 end-lease)."""
        ldr = self._leader()
        ldr.relinquish_lease()
        self.cluster.loop.run_until(self.cluster.loop.now + 0.2)
        ldr.crash()

    def stats(self) -> dict:
        return {
            "consistency": self.read_mode.value,
            "reads": self.reads,
            "read_messages": self.read_messages,
            "messages_total": self.cluster.net.messages_sent,
            "leader": self.cluster.directory.leader_id,
            "term": self.cluster.directory.leader_term,
        }

"""Figs. 6 & 10: effect of one-way network latency on 90th-percentile
read/write latency, per consistency mechanism.

Paper finding: quorum checks make reads as slow as writes (one roundtrip)
and push write latency up via I/O contention; LeaseGuard makes consistent
reads as fast as inconsistent reads (zero roundtrips, ~0 added latency).

Setup mirrors §6.4: lognormal one-way latencies with variance = mean,
means 1–10 ms; open-loop clients, half reads half appends.
"""

from __future__ import annotations

from repro.consistency import benchmark_configs, split_bench_config
from repro.core import RaftParams, SimParams, run_workload


def run(quick: bool = False) -> list[dict]:
    # one row per registered policy (no ablation variants in this figure)
    mechanisms = benchmark_configs(variants=False)
    latencies_ms = [1.0, 5.0, 10.0] if quick else [1.0, 2.0, 5.0, 10.0]
    rows = []
    for lat_ms in latencies_ms:
        for name, config in mechanisms.items():
            flags, sim_flags = split_bench_config(config)
            raft = RaftParams(election_timeout=2.0, heartbeat_interval=0.2,
                              rpc_timeout=1.0, **flags)
            sim = SimParams(
                seed=6,
                one_way_latency_mean=lat_ms * 1e-3,
                one_way_latency_variance=lat_ms * 1e-3,  # variance = mean (§6.4)
                sim_duration=2.0 if quick else 5.0,
                interarrival=0.1 if not quick else 0.05,
                write_fraction=0.5,
                **sim_flags,
            )
            res = run_workload(raft, sim, check=not quick, settle_time=3.0)
            s = res.summarize()
            rows.append({
                "mechanism": name,
                "one_way_ms": lat_ms,
                "read_p90_ms": s["read_p90"] * 1e3,
                "write_p90_ms": s["write_p90"] * 1e3,
                "reads_ok": res.reads_ok,
                "writes_ok": res.writes_ok,
            })
    return rows

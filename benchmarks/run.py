"""Benchmark harness: one module per paper table/figure.

Usage:
    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig7]

Prints one CSV block per figure, plus a final ``name,us_per_call,derived``
summary line per benchmark for harness compatibility.
"""

from __future__ import annotations

import argparse
import sys
import time

from . import (fig5_lease_duration, fig6_latency, fig7_availability,
               fig8_skewness, fig11_scalability)
from .common import emit

FIGS = {
    "fig5_lease_duration": fig5_lease_duration.run,
    "fig6_latency": fig6_latency.run,
    "fig7_availability": fig7_availability.run,
    "fig7_headline": fig7_availability.summarize_post_election_reads,
    "fig8_skewness": fig8_skewness.run,
    "fig11_scalability": fig11_scalability.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--roofline", action="store_true",
                    help="also run the data-plane roofline benchmark "
                         "(slow: compiles dry-run cells)")
    args = ap.parse_args()

    summary = []
    for name, fn in FIGS.items():
        if args.only and args.only not in name:
            continue
        print(f"\n== {name} ==", flush=True)
        t0 = time.time()
        rows = fn(quick=args.quick)
        dt = time.time() - t0
        emit(rows)
        summary.append((name, dt * 1e6 / max(1, len(rows)), len(rows)))

    if args.roofline:
        from . import roofline_bench
        print("\n== roofline ==", flush=True)
        t0 = time.time()
        rows = roofline_bench.run(quick=args.quick)
        dt = time.time() - t0
        emit(rows)
        summary.append(("roofline", dt * 1e6 / max(1, len(rows)), len(rows)))

    print("\nname,us_per_call,derived")
    for name, us, n in summary:
        print(f"{name},{us:.1f},rows={n}")


if __name__ == "__main__":
    main()

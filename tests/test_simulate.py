"""Unit tests for the deterministic event loop / task layer."""

import pytest

from repro.core.simulate import (Condition, Event, EventLoop, Future, Task,
                                 TimeoutError_, wait_for)


def test_callbacks_ordered_by_time_then_fifo():
    loop = EventLoop()
    order = []
    loop.call_later(0.2, lambda: order.append("b"))
    loop.call_later(0.1, lambda: order.append("a"))
    loop.call_later(0.2, lambda: order.append("c"))  # same time: FIFO
    loop.run()
    assert order == ["a", "b", "c"]
    assert loop.now == pytest.approx(0.2)


def test_task_await_sleep_advances_time():
    loop = EventLoop()

    async def main():
        await loop.sleep(1.5)
        return loop.now

    t = loop.create_task(main())
    out = loop.run_until_complete(t)
    assert out == pytest.approx(1.5)


def test_nested_tasks_and_futures():
    loop = EventLoop()

    async def child(x):
        await loop.sleep(0.1)
        return x * 2

    async def main():
        a = loop.create_task(child(3))
        b = loop.create_task(child(4))
        return await a + await b

    assert loop.run_until_complete(loop.create_task(main())) == 14


def test_wait_for_timeout():
    loop = EventLoop()
    never = Future(loop)

    async def main():
        with pytest.raises(TimeoutError_):
            await wait_for(never, 0.5)
        return "done"

    assert loop.run_until_complete(loop.create_task(main())) == "done"
    assert loop.now == pytest.approx(0.5)


def test_exception_propagates_through_await():
    loop = EventLoop()

    async def boom():
        await loop.sleep(0.01)
        raise ValueError("x")

    async def main():
        with pytest.raises(ValueError):
            await loop.create_task(boom())
        return 1

    assert loop.run_until_complete(loop.create_task(main())) == 1


def test_event_and_condition():
    loop = EventLoop()
    ev = Event(loop)
    cond = Condition(loop)
    state = {"n": 0}
    results = []

    async def waiter():
        await ev.wait()
        await cond.wait_until(lambda: state["n"] >= 2)
        results.append(loop.now)

    loop.create_task(waiter())
    loop.call_later(0.3, ev.set)

    def bump():
        state["n"] += 1
        cond.notify_all()

    loop.call_later(0.5, bump)
    loop.call_later(0.7, bump)
    loop.run()
    assert results == [pytest.approx(0.7)]


def test_run_until_does_not_execute_future_events():
    loop = EventLoop()
    fired = []
    loop.call_later(1.0, lambda: fired.append(1))
    loop.run_until(0.5)
    assert not fired and loop.now == 0.5
    loop.run_until(1.5)
    assert fired == [1]


def test_condition_wait_timeout_purges_waiter():
    """Timed-out Condition waiters must be removed immediately — an idle
    Raft leader parks on a Condition every heartbeat tick, and leaking one
    resolved future per tick grows the waiter list without bound."""
    loop = EventLoop()
    cond = Condition(loop)
    woke = []

    async def parked():
        for _ in range(50):
            await cond.wait(timeout=0.1)   # times out every iteration
            woke.append(loop.now)

    loop.create_task(parked())
    loop.run_until(10.0)
    assert len(woke) == 50
    assert cond._waiters == []


def test_condition_wait_notify_before_timeout():
    loop = EventLoop()
    cond = Condition(loop)
    woke = []

    async def parked():
        await cond.wait(timeout=5.0)
        woke.append(loop.now)

    loop.create_task(parked())
    loop.call_later(0.2, cond.notify_all)
    loop.run_until(10.0)                   # late timeout must be a no-op
    assert woke == [pytest.approx(0.2)]
    assert cond._waiters == []

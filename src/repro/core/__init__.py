"""LeaseGuard core: Raft + leases, deterministic simulation (the paper)."""

from .checker import LinearizabilityError, check_linearizability
from .client import ClientLogEntry, Directory, Workload
from .clock import BoundedClock, TimeInterval
from .network import NetParams, Network
from .params import RaftParams, ReadMode, SimParams
from .raft import (CONFIG, END_LEASE, NOOP, LogEntry, Node, ReadResult,
                   WriteResult, encode_config, parse_config)
from .runner import Cluster, RunResult, build_cluster, run_workload, throughput_timeline
from .simulate import Condition, Event, EventLoop, Future, Task, TimeoutError_, wait_for

__all__ = [
    "LinearizabilityError", "check_linearizability", "ClientLogEntry",
    "Directory", "Workload", "BoundedClock", "TimeInterval", "NetParams",
    "Network", "RaftParams", "ReadMode", "SimParams", "END_LEASE", "NOOP",
    "LogEntry", "Node", "ReadResult", "WriteResult", "encode_config",
    "parse_config", "CONFIG", "Cluster", "RunResult",
    "build_cluster", "run_workload", "throughput_timeline", "Condition",
    "Event", "EventLoop", "Future", "Task", "TimeoutError_", "wait_for",
]

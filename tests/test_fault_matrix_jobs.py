"""Parallel fault-matrix determinism: ``--jobs N`` must be a pure
throughput knob. The artifact is the committed record of the fault
campaign, so sharding across workers is only acceptable if the bytes
that land on disk are identical to the serial run."""

import pytest

from benchmarks import fault_matrix


@pytest.mark.slow
def test_jobs_sharding_is_byte_identical(tmp_path):
    """The CI smoke slice (6 scenarios x 2 policies x 5 seeds), run
    serially and with 4 workers: round-robin sharding + ordered merge
    must reproduce the exact artifact bytes, not just equivalent JSON."""
    serial = tmp_path / "serial.json"
    sharded = tmp_path / "sharded.json"
    fault_matrix.main(["--smoke", "--jobs", "1", "--out", str(serial)])
    fault_matrix.main(["--smoke", "--jobs", "4", "--out", str(sharded)])
    assert serial.read_bytes() == sharded.read_bytes()


def test_round_robin_merge_restores_canonical_order():
    """The de-interleave merge is exact for shard counts that do and
    don't divide the cell count (the off-by-one tail case)."""
    for n, jobs in [(12, 4), (13, 4), (7, 3), (5, 8), (1, 2)]:
        cells = list(range(n))
        shards = [cells[k::jobs] for k in range(jobs)]
        iters = [iter(s) for s in shards]
        merged = [next(iters[i % jobs]) for i in range(n)]
        assert merged == cells

"""Checkpointing with LeaseGuard-committed manifests.

Layout: ``<dir>/step_N/arrays.npz`` (flattened pytree leaves) +
``<dir>/step_N/manifest.json``. The manifest is only authoritative once it
is **committed through the coordinator's Raft log** (coord/registry):
a trainer that crashes mid-save leaves a dangling directory but the
cluster-visible "latest checkpoint" never points at a torn write. On
restart, ``latest_step()`` is a zero-roundtrip leased read.

This is the paper's mechanism doing real work in a training system: the
checkpoint commit is a Raft write; restart discovery is a linearizable
read that costs no quorum roundtrip.
"""

from __future__ import annotations

import hashlib
import json
import os
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":
            # npz has no native bf16; f32 upcast is lossless and
            # restore_checkpoint casts back to the template dtype
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(directory: str, step: int, state: Any,
                    extra: Optional[dict] = None,
                    registry=None) -> dict:
    """Write arrays + manifest; commit the manifest via the registry
    (LeaseGuard Raft) if one is provided. Returns the manifest."""
    path = os.path.join(directory, f"step_{step}")
    os.makedirs(path, exist_ok=True)
    flat = _flatten(state)
    npz_path = os.path.join(path, "arrays.npz")
    np.savez(npz_path, **flat)
    digest = hashlib.sha256()
    with open(npz_path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            digest.update(chunk)
    manifest = {
        "step": step,
        "path": path,
        "n_arrays": len(flat),
        "sha256": digest.hexdigest(),
        "extra": extra or {},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if registry is not None:
        res = registry.commit_checkpoint(manifest)
        if not res:
            raise RuntimeError("coordinator rejected checkpoint commit")
    return manifest


def restore_checkpoint(state_template: Any, manifest: dict) -> Any:
    """Rebuild the pytree from a committed manifest."""
    npz = np.load(os.path.join(manifest["path"], "arrays.npz"))
    paths, treedef = jax.tree_util.tree_flatten_with_path(state_template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        arr = npz[key]
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def verify_checkpoint(manifest: dict) -> bool:
    npz_path = os.path.join(manifest["path"], "arrays.npz")
    if not os.path.exists(npz_path):
        return False
    digest = hashlib.sha256()
    with open(npz_path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            digest.update(chunk)
    return digest.hexdigest() == manifest["sha256"]

"""Training-cluster metadata on top of the LeaseGuard coordinator.

Three tables, all backed by the replicated linearizable KV:

* **checkpoint registry** — a checkpoint exists once its manifest is
  committed through the Raft log; ``latest_checkpoint()`` is the
  paper's zero-roundtrip leased read (on a 1000-node fleet every worker
  polls this every step — with quorum reads that poll would be the
  coordinator's bottleneck; with LeaseGuard it is free);
* **membership** — workers register, heartbeat, and deregister; all
  three are events in ONE append-only log (``members/log``) folded in
  log order, so a worker that leaves and later re-registers is live
  again (a set-difference over separate join/leave tables would kill it
  forever). ``live_workers(ttl=...)`` additionally requires a heartbeat
  (or join) within the last ``ttl`` simulated seconds;
* **straggler table** — per-worker step-time reports; the launcher flags
  workers slower than ``threshold ×`` the fleet median, computed over a
  *per-worker* recent window (a global window would let fast, frequent
  reporters evict slow workers from the sample entirely).

Two client shapes share the same schema and fold helpers:
:class:`ClusterRegistry` (synchronous, over the crank adapter) for
wall-clock trainers, and :class:`AsyncClusterRegistry` (awaitable, over
:class:`~repro.coord.kvstore.CoordClient`) for actors living on the
simulated event loop — the fleet simulator's workers.
"""

from __future__ import annotations

import statistics
from typing import Any, Optional

from .kvstore import CoordClient, LocalCoordinator

CKPT_KEY = "ckpt/manifest"
MEMBERS_KEY = "members/log"
REPORTS_KEY = "stragglers/reports"


# ------------------------------------------------------------ fold helpers
def fold_members(events: list[dict]) -> dict[str, dict]:
    """Fold join/leave/heartbeat events **in log order** into the current
    membership: ``wid -> {"meta", "last_seen"}``. A leave removes the
    worker; a later join resurrects it (the rejoin path a join-set minus
    leave-set difference gets wrong). Heartbeats only refresh workers
    that are currently registered."""
    members: dict[str, dict] = {}
    for r in events:
        ev, wid = r["ev"], r["id"]
        if ev == "join":
            members[wid] = {"meta": r.get("meta") or {},
                            "last_seen": r.get("t", 0.0)}
        elif ev == "leave":
            members.pop(wid, None)
        elif ev == "hb":
            m = members.get(wid)
            if m is not None and r.get("t", 0.0) > m["last_seen"]:
                m["last_seen"] = r["t"]
    return members


def live_from(events: list[dict], now: Optional[float] = None,
              ttl: Optional[float] = None) -> set[str]:
    """Live worker ids from a folded event log. ``ttl=None`` is pure
    membership; with a TTL, a worker is live only if its last join or
    heartbeat is at most ``ttl`` seconds old."""
    members = fold_members(events)
    if ttl is None:
        return set(members)
    assert now is not None, "ttl-based liveness needs the current time"
    return {wid for wid, m in members.items()
            if now - m["last_seen"] <= ttl}


def straggler_flags_from(reports: list[dict], threshold: float = 1.5,
                         window: int = 64) -> dict[str, bool]:
    """Flag workers whose recent mean step time exceeds ``threshold ×``
    the fleet median. The window is applied **per worker** (each
    worker's last ``window`` reports) before pooling for the median —
    a single global ``[-window:]`` slice would let fast, frequent
    reporters push slow workers out of the sample."""
    per: dict[str, list[float]] = {}
    for r in reports:
        per.setdefault(r["id"], []).append(r["s"])
    per = {wid: xs[-window:] for wid, xs in per.items()}
    if not per:
        return {}
    med = statistics.median(s for xs in per.values() for s in xs)
    return {wid: statistics.fmean(xs) > threshold * med
            for wid, xs in per.items()}


class ClusterRegistry:
    def __init__(self, coord: Optional[LocalCoordinator] = None,
                 consistency: Optional[str] = None) -> None:
        """``consistency`` selects a policy from the ``repro.consistency``
        registry by name (default: leaseguard). Ignored when ``coord`` is
        supplied."""
        if coord is None:
            coord = (LocalCoordinator() if consistency is None
                     else LocalCoordinator(read_mode=consistency))
        self.coord = coord

    def _now(self) -> float:
        return self.coord.cluster.loop.now

    # -- checkpoints -------------------------------------------------------
    def commit_checkpoint(self, manifest: dict) -> bool:
        self.coord.append(CKPT_KEY, manifest)
        return True

    def latest_checkpoint(self) -> Optional[dict]:
        return self.coord.read_latest(CKPT_KEY)

    def checkpoint_history(self) -> list[dict]:
        return self.coord.read_list(CKPT_KEY)

    # -- membership --------------------------------------------------------
    def register_worker(self, worker_id: str, meta: Optional[dict] = None) -> None:
        self.coord.append(MEMBERS_KEY, {"ev": "join", "id": worker_id,
                                        "meta": meta or {}, "t": self._now()})

    def deregister_worker(self, worker_id: str) -> None:
        self.coord.append(MEMBERS_KEY, {"ev": "leave", "id": worker_id,
                                        "t": self._now()})

    def heartbeat(self, worker_id: str) -> None:
        """Liveness ping; feeds ``live_workers(ttl=...)``."""
        self.coord.append(MEMBERS_KEY, {"ev": "hb", "id": worker_id,
                                        "t": self._now()})

    def live_workers(self, ttl: Optional[float] = None) -> set[str]:
        events = self.coord.read_list(MEMBERS_KEY)
        return live_from(events, now=self._now(), ttl=ttl)

    # -- stragglers ---------------------------------------------------------
    def report_step_time(self, worker_id: str, step: int,
                         seconds: float) -> None:
        self.coord.append(REPORTS_KEY,
                          {"id": worker_id, "step": step, "s": seconds})

    def straggler_flags(self, threshold: float = 1.5,
                        window: int = 64) -> dict[str, bool]:
        """Workers whose recent mean step time exceeds threshold× the
        fleet median. Zero-roundtrip read: callable every step."""
        reports = self.coord.read_list(REPORTS_KEY)
        return straggler_flags_from(reports, threshold, window)


class AsyncClusterRegistry:
    """Awaitable twin of :class:`ClusterRegistry` for actors that share
    the cluster's event loop (the fleet simulator's training workers).
    Mutators return False (and liveness reads None) instead of raising
    when the control plane is unavailable past the client's op timeout —
    actor loops skip the tick and retry on their own cadence."""

    def __init__(self, client: CoordClient) -> None:
        self.client = client

    def _now(self) -> float:
        return self.client.loop.now

    # -- membership --------------------------------------------------------
    async def register_worker(self, worker_id: str,
                              meta: Optional[dict] = None) -> bool:
        res = await self.client.append(
            MEMBERS_KEY, {"ev": "join", "id": worker_id,
                          "meta": meta or {}, "t": self._now()},
            idempotent=True)
        return res.ok

    async def deregister_worker(self, worker_id: str) -> bool:
        res = await self.client.append(
            MEMBERS_KEY, {"ev": "leave", "id": worker_id, "t": self._now()},
            idempotent=True)
        return res.ok

    async def heartbeat(self, worker_id: str) -> bool:
        res = await self.client.append(
            MEMBERS_KEY, {"ev": "hb", "id": worker_id, "t": self._now()},
            idempotent=True)
        return res.ok

    async def live_workers(self, ttl: Optional[float] = None
                           ) -> Optional[set[str]]:
        res = await self.client.read_raw(MEMBERS_KEY)
        if not res.ok:
            return None
        return live_from(self.client.decode(res.value),
                         now=self._now(), ttl=ttl)

    # -- stragglers ---------------------------------------------------------
    async def report_step_time(self, worker_id: str, step: int,
                               seconds: float) -> bool:
        res = await self.client.append(
            REPORTS_KEY, {"id": worker_id, "step": step, "s": seconds},
            idempotent=True)
        return res.ok

    async def straggler_flags(self, threshold: float = 1.5,
                              window: int = 64) -> Optional[dict[str, bool]]:
        res = await self.client.read_raw(REPORTS_KEY)
        if not res.ok:
            return None
        return straggler_flags_from(self.client.decode(res.value),
                                    threshold, window)

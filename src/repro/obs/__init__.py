"""Flight recorder: causal event tracing, unified metrics, forensics.

The observability layer has four pieces:

* :mod:`repro.obs.trace` — the :class:`Tracer`, a typed, schema-versioned
  event recorder attached to the :class:`~repro.core.simulate.EventLoop`.
  Default-off: every instrumentation site in the simulator guards on
  ``loop.tracer is not None`` and makes ZERO PRNG draws, so untraced runs
  replay bit-identically and traced runs are draw-order-neutral.
* :mod:`repro.obs.metrics` — the :class:`Metrics` registry (counters,
  gauges, sim-time histograms keyed by node id) that supersedes the
  ad-hoc ``loop_stats``/``net_stats``/``raft_stats`` dicts behind the
  same names, plus derived per-run series (leader-uptime timeline,
  lease-coverage fraction, read-stall histogram, election-to-first-commit
  and fault-trigger→detection latencies).
* :mod:`repro.obs.export` / :mod:`repro.obs.schema` — JSONL trace dumps
  (byte-identical per seed), Chrome ``trace_event`` output for
  Perfetto / ``chrome://tracing``, and a hand-rolled schema validator.
* :mod:`repro.obs.explain` — the forensics CLI
  (``python -m repro.obs.explain <trace.jsonl>``) that reconstructs "why
  did this read stall/fail" from the causal parent chain, plus the
  compact digest embedded in flagged matrix-artifact rows.
* :mod:`repro.obs.probes` — offline invariant passes over traces, e.g.
  :func:`~repro.obs.probes.at_most_one_lease_holder`, an independent
  re-derivation of LeaseGuard's safety argument beside the
  linearizability checker.
"""

from .metrics import Metrics, derive_headline_series
from .probes import at_most_one_lease_holder
from .schema import SCHEMA_VERSION, validate_events, validate_jsonl
from .trace import Tracer

__all__ = [
    "Tracer", "Metrics", "derive_headline_series",
    "at_most_one_lease_holder", "SCHEMA_VERSION",
    "validate_events", "validate_jsonl",
]

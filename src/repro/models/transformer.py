"""Unified decoder stack covering all 10 architectures.

One scan-over-layers decoder parameterized by ArchConfig:
* dense / MoE SwiGLU MLPs (+ arctic's parallel dense residual)
* GQA attention with RoPE, optional qk_norm / QKV bias / sliding window
* RWKV6 blocks (attention-free)
* hymba hybrid blocks (parallel attention + mamba heads)
* VLM/audio variants take precomputed frontend embeddings (stub)

Layers are stacked (leading axis = layer) and applied with ``lax.scan`` —
compile time is O(1) in depth; remat is applied per layer for training.

Three entry points:
  forward_train   tokens/embeds -> chunked-CE loss (never materializes
                  the full (B,S,V) logits)
  prefill         tokens/embeds -> (last-token logits, decode caches)
  decode_step     one token + caches -> (logits, caches)
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.ctx import constrain
from . import ssm
from .layers import (apply_rope, causal_attention_ref, decode_attention_ref,
                     dense_init, repeat_kv, rms_norm, rope_tables)
from .moe import apply_moe, init_moe

LOSS_CHUNK = 1024


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


# ================================================================= init
def init_attn(key: jax.Array, cfg: ArchConfig, dtype) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), dtype),
        "wk": dense_init(ks[1], (d, hkv * hd), dtype),
        "wv": dense_init(ks[2], (d, hkv * hd), dtype),
        "wo": dense_init(ks[3], (h * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def init_layer(key: jax.Array, cfg: ArchConfig) -> dict:
    dtype = _dtype(cfg)
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {
        "ln1": jnp.ones((d,), jnp.float32),
        "ln2": jnp.ones((d,), jnp.float32),
    }
    if cfg.attn_free:
        p["tmix"] = ssm.init_rwkv_tmix(ks[0], cfg, dtype)
        p["cmix"] = ssm.init_rwkv_cmix(ks[1], cfg, dtype)
        return p
    p["attn"] = init_attn(ks[0], cfg, dtype)
    if cfg.hybrid_ssm:
        p["mamba"] = ssm.init_mamba(ks[1], cfg, dtype)
    if cfg.is_moe:
        p["moe"] = init_moe(ks[2], cfg, dtype)
    else:
        p["mlp"] = {
            "w_gate": dense_init(ks[2], (d, f), dtype),
            "w_up": dense_init(ks[3], (d, f), dtype),
            "w_down": dense_init(ks[4], (f, d), dtype),
        }
    return p


def init_params(key: jax.Array, cfg: ArchConfig) -> dict:
    dtype = _dtype(cfg)
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    params = {
        "embed": dense_init(k_embed, (cfg.vocab_size, cfg.d_model), dtype),
        "layers": jax.vmap(lambda k: init_layer(k, cfg))(layer_keys),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            k_head, (cfg.d_model, cfg.vocab_size), dtype)
    return params


# ============================================================ attention
def _qkv(p: dict, x: jax.Array, cfg: ArchConfig):
    b, s, _ = x.shape
    q = constrain(x @ p["wq"], "dp", None, "tp")
    k = constrain(x @ p["wk"], "dp", None, "tp")
    v = constrain(x @ p["wv"], "dp", None, "tp")
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    # re-constrain per-HEAD sharding after the reshape: without this, the
    # flat 'tp' sharding fractures heads when H % tp != 0 (arctic: 56
    # heads / 16) and attention contracts across shards -> partial-score
    # all-reduces (§Perf iteration 4: -15 s/step on arctic). GSPMD pads
    # uneven head counts.
    q = constrain(q.reshape(b, s, cfg.n_heads, cfg.hd),
                  "dp", None, "tp", None)
    k = constrain(k.reshape(b, s, cfg.n_kv_heads, cfg.hd),
                  "dp", None, "tp", None)
    v = constrain(v.reshape(b, s, cfg.n_kv_heads, cfg.hd),
                  "dp", None, "tp", None)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def apply_attn_seq(p: dict, x: jax.Array, cfg: ArchConfig,
                   rope: tuple) -> tuple[jax.Array, dict]:
    """Full-sequence attention; returns output and the (k, v) for caching.
    ``rope``: precomputed (cos, sin) tables (hoisted out of the layer
    scan — loop-invariant)."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    q = apply_rope(q, rope)
    k = apply_rope(k, rope)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    out = causal_attention_ref(q, repeat_kv(k, n_rep), repeat_kv(v, n_rep),
                               window=cfg.sliding_window)
    out = constrain(out.reshape(b, s, cfg.n_heads * cfg.hd),
                    "dp", None, "tp")
    out = constrain(out @ p["wo"], "dp", "sp", None)
    return out, {"k": k, "v": v}


def apply_attn_decode(p: dict, x: jax.Array, cfg: ArchConfig,
                      cache: dict, pos: jax.Array) -> tuple[jax.Array, dict]:
    """One-token decode against a (possibly ring-buffered SWA) KV cache.

    cache: {"k": (B, C, Hkv, hd), "v": ...}; C = min(S_max, window).
    pos: (B,) absolute position of the new token.
    """
    b, s, _ = x.shape
    assert s == 1
    q, k, v = _qkv(p, x, cfg)
    rope = rope_tables(pos[:, None], cfg.hd, cfg.rope_theta)
    q = apply_rope(q, rope)
    k = apply_rope(k, rope)
    cache_size = cache["k"].shape[1]
    slot = (pos % cache_size).astype(jnp.int32)
    k_cache = jax.vmap(lambda c, kk, i: jax.lax.dynamic_update_slice(
        c, kk, (i, 0, 0)))(cache["k"], k, slot)
    v_cache = jax.vmap(lambda c, vv, i: jax.lax.dynamic_update_slice(
        c, vv, (i, 0, 0)))(cache["v"], v, slot)
    cache_len = jnp.minimum(pos + 1, cache_size)
    # ring buffer holds exactly the window; mask by valid slot count only.
    # GQA handled inside (no repeat_kv: §Perf iteration 5b).
    out = decode_attention_ref(q, k_cache, v_cache, cache_len, window=None)
    out = out.reshape(b, 1, cfg.n_heads * cfg.hd) @ p["wo"]
    return out, {"k": k_cache, "v": v_cache}


# =============================================================== blocks
def apply_block_seq(lp: dict, x: jax.Array, cfg: ArchConfig,
                    rope: tuple):
    """One layer over a full sequence. Returns (x, aux_loss, cache)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.attn_free:
        h, tstate = ssm.apply_rwkv_tmix(lp["tmix"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg)
        x = x + h
        h, cstate = ssm.apply_rwkv_cmix(lp["cmix"], rms_norm(x, lp["ln2"], cfg.norm_eps), cfg)
        x = x + h
        cache = {"tmix": tstate, "cmix": cstate}
        return x, aux, cache
    x = constrain(x, "dp", "sp", None)   # seq-parallel residual stream
    normed = rms_norm(x, lp["ln1"], cfg.norm_eps)
    attn_out, kv = apply_attn_seq(lp["attn"], normed, cfg, rope)
    if cfg.hybrid_ssm:
        ssm_out, mstate = ssm.apply_mamba(lp["mamba"], normed, cfg)
        x = x + 0.5 * (attn_out + ssm_out)
        cache = {"kv": kv, "mamba": mstate}
    else:
        x = x + constrain(attn_out, "dp", "sp", None)
        cache = {"kv": kv}
    normed2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        b, s, d = normed2.shape
        out, aux = apply_moe(lp["moe"], normed2.reshape(b * s, d), cfg)
        x = x + constrain(out.reshape(b, s, d), "dp", "sp", None)
    else:
        m = lp["mlp"]
        g = constrain(normed2 @ m["w_gate"], "dp", None, "tp")
        u = constrain(normed2 @ m["w_up"], "dp", None, "tp")
        x = x + constrain(jax.nn.silu(g) * u @ m["w_down"],
                          "dp", "sp", None)
    return x, aux, cache


def apply_block_decode(lp: dict, x: jax.Array, cfg: ArchConfig,
                       cache: dict, pos: jax.Array):
    """One layer for one decode token. Returns (x, new_cache)."""
    if cfg.attn_free:
        normed = rms_norm(x, lp["ln1"], cfg.norm_eps)
        h, tstate = ssm.apply_rwkv_tmix(lp["tmix"], normed, cfg,
                                        state=cache["tmix"])
        x = x + h
        normed = rms_norm(x, lp["ln2"], cfg.norm_eps)
        h, cstate = ssm.apply_rwkv_cmix(lp["cmix"], normed, cfg,
                                        state=cache["cmix"])
        x = x + h
        return x, {"tmix": tstate, "cmix": cstate}
    normed = rms_norm(x, lp["ln1"], cfg.norm_eps)
    attn_out, kv = apply_attn_decode(lp["attn"], normed, cfg, cache["kv"], pos)
    if cfg.hybrid_ssm:
        ssm_out, mstate = ssm.apply_mamba(lp["mamba"], normed, cfg,
                                          state=cache["mamba"])
        x = x + 0.5 * (attn_out + ssm_out)
        new_cache = {"kv": kv, "mamba": mstate}
    else:
        x = x + attn_out
        new_cache = {"kv": kv}
    normed2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        b, s, d = normed2.shape
        out, _ = apply_moe(lp["moe"], normed2.reshape(b * s, d), cfg)
        x = x + out.reshape(b, s, d)
    else:
        m = lp["mlp"]
        x = x + jax.nn.silu(normed2 @ m["w_gate"]) * (normed2 @ m["w_up"]) \
            @ m["w_down"]
    return x, new_cache


# ============================================================== forward
def _embed_inputs(params: dict, cfg: ArchConfig, batch: dict) -> jax.Array:
    if cfg.embedding_stub:
        # VLM/audio: precomputed patch/frame embeddings from the frontend
        return constrain(batch["embeds"].astype(_dtype(cfg)),
                         "dp", None, None)
    return constrain(params["embed"][batch["tokens"]], "dp", None, None)


def _stack_layers(params: dict, cfg: ArchConfig, x: jax.Array,
                  rope: tuple, with_cache: bool,
                  remat: bool, unroll: bool = False):
    def body(carry, lp):
        x, aux = carry
        x, a, cache = apply_block_seq(lp, x, cfg, rope)
        out = cache if with_cache else None
        return (x, aux + a), out

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    carry0 = (x, jnp.zeros((), jnp.float32))
    if unroll:
        # python-loop unroll (debug/validation: XLA cost_analysis counts
        # every op; no while-loop trip ambiguity)
        caches = []
        carry = carry0
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            carry, out = body(carry, lp)
            caches.append(out)
        x, aux = carry
        caches = None if not with_cache else jax.tree.map(
            lambda *xs: jnp.stack(xs), *caches)
        return x, aux, caches
    (x, aux), caches = jax.lax.scan(body, carry0, params["layers"])
    return x, aux, caches


def _rope_for(cfg: ArchConfig, s: int) -> tuple:
    if cfg.attn_free:
        return ()
    # 1-D positions: broadcast over batch AND heads without materializing
    return rope_tables(jnp.arange(s), cfg.hd, cfg.rope_theta)


def hidden_states(params: dict, cfg: ArchConfig, batch: dict,
                  remat: Optional[bool] = None) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward to final hidden states (pre-head)."""
    x = _embed_inputs(params, cfg, batch)
    b, s, _ = x.shape
    use_remat = cfg.remat if remat is None else remat
    x, aux, _ = _stack_layers(params, cfg, x, _rope_for(cfg, s),
                              with_cache=False, remat=use_remat)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def lm_head_weight(params: dict, cfg: ArchConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def forward_train(params: dict, cfg: ArchConfig, batch: dict) -> jax.Array:
    """Mean next-token cross-entropy, chunked over the sequence so the
    full (B, S, V) logits are never materialized."""
    h, aux = hidden_states(params, cfg, batch)
    labels = batch["labels"]
    w = lm_head_weight(params, cfg)
    b, s, d = h.shape
    n_chunks = max(1, s // min(LOSS_CHUNK, s))
    chunk = s // n_chunks
    h_c = h.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    l_c = labels.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    def ce(carry, hc_lc):
        hc, lc = hc_lc
        logits = constrain((hc @ w).astype(jnp.float32),
                           "dp", None, "tp")             # (B, C, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(ce, jnp.zeros((), jnp.float32), (h_c, l_c))
    loss = total / (b * n_chunks * chunk)
    return loss + 0.01 * aux


def prefill(params: dict, cfg: ArchConfig, batch: dict):
    """Returns (last-token logits, caches, positions) for decoding."""
    x = _embed_inputs(params, cfg, batch)
    b, s, _ = x.shape
    x, _, caches = _stack_layers(params, cfg, x, _rope_for(cfg, s),
                                 with_cache=True, remat=False)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, -1, :] @ lm_head_weight(params, cfg)).astype(jnp.float32)
    if not cfg.attn_free and caches is not None:
        # prefill caches: reorder kv to (L, B, S, Hkv, hd) is already so
        pass
    return logits, caches, jnp.full((b,), s, jnp.int32)


def init_decode_cache(cfg: ArchConfig, batch_size: int, max_len: int) -> dict:
    """Blank decode caches (used to lower serve_step without a prefill)."""
    dtype = _dtype(cfg)
    L = cfg.n_layers

    def per_layer():
        if cfg.attn_free:
            h = cfg.d_model // cfg.rwkv_head_dim
            return {
                "tmix": {"shift": jnp.zeros((batch_size, cfg.d_model), dtype),
                         "wkv": jnp.zeros((batch_size, h, cfg.rwkv_head_dim,
                                           cfg.rwkv_head_dim), jnp.float32)},
                "cmix": jnp.zeros((batch_size, cfg.d_model), dtype),
            }
        size = max_len if cfg.sliding_window is None \
            else min(max_len, cfg.sliding_window)
        c = {"kv": {
            "k": jnp.zeros((batch_size, size, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((batch_size, size, cfg.n_kv_heads, cfg.hd), dtype),
        }}
        if cfg.hybrid_ssm:
            di = cfg.n_heads * cfg.hd
            c["mamba"] = {
                "conv": jnp.zeros((batch_size, ssm.CONV_K - 1, di), dtype),
                "h": jnp.zeros((batch_size, di, cfg.ssm_state), jnp.float32),
            }
        return c

    one = per_layer()
    return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (L,) + a.shape),
                        one)


def decode_step(params: dict, cfg: ArchConfig, tokens: jax.Array,
                caches: dict, pos: jax.Array):
    """One decoding step. tokens: (B,) int32 (or (B,D) embeds for stub
    archs); pos: (B,) absolute positions. Returns (logits, new_caches)."""
    if cfg.embedding_stub:
        x = tokens.astype(_dtype(cfg))[:, None, :]
    else:
        x = params["embed"][tokens][:, None, :]

    def body(x, lp_cache):
        lp, cache = lp_cache
        x, new_cache = apply_block_decode(lp, x, cfg, cache, pos)
        return x, new_cache

    x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, 0, :] @ lm_head_weight(params, cfg)).astype(jnp.float32)
    return logits, new_caches

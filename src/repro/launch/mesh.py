"""Production mesh builders.

Single pod: 16×16 = 256 chips (v5e pod), axes (data, model).
Multi-pod:  2×16×16 = 512 chips, axes (pod, data, model) — the pod axis is
pure data parallelism (params replicated across pods; only the per-step
gradient all-reduce crosses the inter-pod DCI links).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 2, model: int = 4, multi_pod: bool = False):
    """Small mesh for CPU-host tests (requires enough host devices)."""
    if multi_pod:
        return jax.make_mesh((2, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


# v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12         # per chip
HBM_BW = 819e9                   # bytes/s per chip
ICI_BW = 50e9                    # bytes/s per link

"""Training-cluster metadata on top of the LeaseGuard coordinator.

Three tables, all backed by the replicated linearizable KV:

* **checkpoint registry** — a checkpoint exists once its manifest is
  committed through the Raft log; ``latest_checkpoint()`` is the
  paper's zero-roundtrip leased read (on a 1000-node fleet every worker
  polls this every step — with quorum reads that poll would be the
  coordinator's bottleneck; with LeaseGuard it is free);
* **membership** — workers register and heartbeat; elastic scaling reads
  the live set to decide the mesh;
* **straggler table** — per-worker step-time reports; the launcher flags
  workers slower than ``threshold ×`` the fleet median.
"""

from __future__ import annotations

import statistics
from typing import Any, Optional

from .kvstore import LocalCoordinator

CKPT_KEY = "ckpt/manifest"


class ClusterRegistry:
    def __init__(self, coord: Optional[LocalCoordinator] = None,
                 consistency: Optional[str] = None) -> None:
        """``consistency`` selects a policy from the ``repro.consistency``
        registry by name (default: leaseguard). Ignored when ``coord`` is
        supplied."""
        if coord is None:
            coord = (LocalCoordinator() if consistency is None
                     else LocalCoordinator(read_mode=consistency))
        self.coord = coord

    # -- checkpoints -------------------------------------------------------
    def commit_checkpoint(self, manifest: dict) -> bool:
        self.coord.append(CKPT_KEY, manifest)
        return True

    def latest_checkpoint(self) -> Optional[dict]:
        return self.coord.read_latest(CKPT_KEY)

    def checkpoint_history(self) -> list[dict]:
        return self.coord.read_list(CKPT_KEY)

    # -- membership --------------------------------------------------------
    def register_worker(self, worker_id: str, meta: Optional[dict] = None) -> None:
        self.coord.append("members/joined", {"id": worker_id,
                                             "meta": meta or {}})

    def deregister_worker(self, worker_id: str) -> None:
        self.coord.append("members/left", {"id": worker_id})

    def live_workers(self) -> set[str]:
        joined = {r["id"] for r in self.coord.read_list("members/joined")}
        left = {r["id"] for r in self.coord.read_list("members/left")}
        return joined - left

    # -- stragglers ---------------------------------------------------------
    def report_step_time(self, worker_id: str, step: int,
                         seconds: float) -> None:
        self.coord.append("stragglers/reports",
                          {"id": worker_id, "step": step, "s": seconds})

    def straggler_flags(self, threshold: float = 1.5,
                        window: int = 64) -> dict[str, bool]:
        """Workers whose recent mean step time exceeds threshold× the
        fleet median. Zero-roundtrip read: callable every step."""
        reports = self.coord.read_list("stragglers/reports")[-window:]
        if not reports:
            return {}
        per: dict[str, list[float]] = {}
        for r in reports:
            per.setdefault(r["id"], []).append(r["s"])
        med = statistics.median(s for xs in per.values() for s in xs)
        return {wid: statistics.fmean(xs) > threshold * med
                for wid, xs in per.items()}

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above run before ANY other import (jax locks the device
count at first init). Smoke tests and benches import other modules and
see 1 device; only this entry point forces 512 host devices.

For every cell we:
  1. build ShapeDtypeStruct inputs (``input_specs`` — no allocation),
  2. jit with explicit in/out shardings on the production mesh,
  3. ``.lower().compile()`` — sharding mismatches / unsupported
     collectives / compile-time OOMs fail here,
  4. print ``memory_analysis()`` (per-device bytes: proves it fits) and
     ``cost_analysis()``,
  5. run the loop-aware HLO roofline analyzer (repro.roofline) and emit
     the three terms + dominant bottleneck.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""

import argparse
import json
import time
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, SHAPES, get_arch, get_shape, shape_applicable
from ..configs.base import ArchConfig, ShapeConfig
from ..models import (decode_step, forward_train, init_decode_cache,
                      init_params, prefill)
from ..roofline import analyze_hlo, roofline_terms
from ..sharding import ctx as shard_ctx
from ..sharding.rules import (batch_specs, cache_specs, param_specs,
                              state_specs, to_named)
from ..train.optimizer import OptConfig
from ..train.train_step import init_train_state, train_step
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh


# ------------------------------------------------------------ input specs
def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        d = {"labels": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.embedding_stub:
            d["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.float32)
        else:
            d["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        return d
    if shape.kind == "prefill":
        d = {}
        if cfg.embedding_stub:
            d["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.float32)
        else:
            d["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        return d
    # decode: one new token against a seq_len-deep cache
    caches = jax.eval_shape(partial(init_decode_cache, cfg, b, max_len=s))
    if cfg.embedding_stub:
        tok = jax.ShapeDtypeStruct((b, cfg.d_model), jnp.float32)
    else:
        tok = jax.ShapeDtypeStruct((b,), i32)
    return {"tokens": tok, "caches": caches,
            "pos": jax.ShapeDtypeStruct((b,), i32)}


def opt_config(cfg: ArchConfig) -> OptConfig:
    return OptConfig(name=cfg.optimizer)


# ------------------------------------------------------------- lowering
def lower_cell(cfg: ArchConfig, shape: ShapeConfig, mesh,
               constraints: bool = True, seq_parallel: bool = False):
    """Returns the lowered computation for one cell on one mesh.

    ``constraints=False`` reproduces the paper-faithful naive-sharding
    baseline (§Perf records both). ``seq_parallel`` toggles iteration 3."""
    if constraints:
        dp, tp = shard_ctx.axes_from_mesh(mesh)
        shard_ctx.set_axes(dp, tp, sp=seq_parallel)
        # group-local MoE dispatch measured WORSE under GSPMD (it cannot
        # partition the capacity scatter: §Perf iteration 6, refuted);
        # flat dispatch stays the default. The grouped path remains
        # selectable for the planned shard_map manual-dispatch follow-up.
        shard_ctx.set_moe_groups(1)
    else:
        shard_ctx.clear()
        shard_ctx.set_moe_groups(1)
    if shape.kind == "train":
        ocfg = opt_config(cfg)
        state_shapes = jax.eval_shape(
            partial(init_train_state, jax.random.PRNGKey(0), cfg, ocfg))
        sspec = state_specs(state_shapes, mesh)
        batch = input_specs(cfg, shape)
        bspec = batch_specs(batch, mesh)
        fn = partial(train_step, cfg=cfg, opt_cfg=ocfg)
        jitted = jax.jit(
            fn,
            in_shardings=(to_named(sspec, mesh), to_named(bspec, mesh)),
            out_shardings=(to_named(sspec, mesh), None),
            donate_argnums=(0,),
        )
        with mesh:
            lowered = jitted.lower(state_shapes, batch)
        return lowered

    params_shapes = jax.eval_shape(
        partial(init_params, jax.random.PRNGKey(0), cfg))
    # serving: TP-only params (no per-token FSDP gathers, §Perf iter 5) —
    # unless the TP-sharded weights alone would blow per-device HBM
    # (arctic-480b: 960 GB bf16 / 16 = 60 GB/dev -> keep FSDP sharding)
    tp_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    tp_only_fits = cfg.param_count() * 2 / tp_size < 8e9
    mode = "serve" if (shape.kind == "decode" and tp_only_fits) else "train"
    pspec = param_specs(params_shapes, mesh, mode=mode)
    if shape.kind == "prefill":
        batch = input_specs(cfg, shape)
        bspec = batch_specs(batch, mesh)

        def fn(params, batch):
            logits, caches, pos = prefill(params, cfg, batch)
            return logits, caches, pos

        jitted = jax.jit(fn, in_shardings=(to_named(pspec, mesh),
                                           to_named(bspec, mesh)))
        with mesh:
            lowered = jitted.lower(params_shapes, batch)
        return lowered

    # decode
    spec_in = input_specs(cfg, shape)
    cspec = cache_specs(spec_in["caches"], mesh)
    tok_spec = batch_specs({"t": spec_in["tokens"]}, mesh)["t"]
    pos_spec = batch_specs({"t": spec_in["pos"]}, mesh)["t"]

    def fn(params, caches, tokens, pos):
        return decode_step(params, cfg, tokens, caches, pos)

    jitted = jax.jit(
        fn,
        in_shardings=(to_named(pspec, mesh), to_named(cspec, mesh),
                      NamedSharding(mesh, tok_spec),
                      NamedSharding(mesh, pos_spec)),
        out_shardings=(None, to_named(cspec, mesh)),
        donate_argnums=(1,),
    )
    with mesh:
        lowered = jitted.lower(params_shapes, spec_in["caches"],
                               spec_in["tokens"], spec_in["pos"])
    return lowered


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """Analytic MODEL_FLOPS: 6·N_active·D train, 2·N_active·D inference,
    plus attention score/value flops."""
    n = cfg.active_param_count()
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = b * s
        flops = 6.0 * n * tokens
        if not cfg.attn_free:
            win = cfg.sliding_window or s
            ctx = min(win, s)
            flops += 3 * 4.0 * b * s * ctx / 2 * cfg.n_heads * cfg.hd
        return flops
    if shape.kind == "prefill":
        tokens = b * s
        flops = 2.0 * n * tokens
        if not cfg.attn_free:
            win = cfg.sliding_window or s
            ctx = min(win, s)
            flops += 4.0 * b * s * ctx / 2 * cfg.n_heads * cfg.hd
        return flops
    # decode: one token each
    flops = 2.0 * n * b
    if not cfg.attn_free:
        ctx = min(cfg.sliding_window or s, s)
        flops += 4.0 * b * ctx * cfg.n_heads * cfg.hd
    return flops


def run_cell(arch_name: str, shape_name: str, mesh, mesh_name: str,
             verbose: bool = True, arch_override: ArchConfig = None) -> dict:
    cfg = arch_override or get_arch(arch_name)
    shape = get_shape(shape_name)
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": why}
    t0 = time.time()
    lowered = lower_cell(cfg, shape, mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    counts = analyze_hlo(hlo)
    n_dev = mesh.devices.size
    terms = roofline_terms(counts, peak_flops=PEAK_FLOPS_BF16,
                           hbm_bw=HBM_BW, ici_bw=ICI_BW)
    mf = model_flops(cfg, shape)
    mf_per_dev = mf / n_dev
    hlo_flops = max(counts.flops, 1.0)
    row = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_name,
        "status": "ok",
        "n_devices": n_dev,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        # per-device numbers (SPMD program)
        "hlo_flops_per_dev": counts.flops,
        "hbm_bytes_per_dev": counts.hbm_bytes,
        "kernel_region_bytes_per_dev": counts.kernel_region_bytes,
        "link_bytes_per_dev": counts.link_bytes,
        "n_collectives": counts.n_collectives,
        "collective_breakdown": {k: round(v)
                                 for k, v in counts.collective_breakdown.items()},
        "compute_s": terms["compute_s"],
        "memory_s": terms["memory_s"],
        "memory_ref_s": terms["memory_ref_s"],
        "collective_s": terms["collective_s"],
        "dominant": terms["dominant"],
        "model_flops_global": mf,
        "useful_flops_ratio": mf_per_dev / hlo_flops,
        "roofline_fraction": min(1.0, (mf_per_dev / PEAK_FLOPS_BF16)
                                 / max(terms["bound_s"], 1e-30)),
        # memory_analysis (per device)
        "arg_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "xla_cost_flops_uncorrected": cost.get("flops", 0.0),
    }
    if verbose:
        hbm_gib = (mem.argument_size_in_bytes + mem.temp_size_in_bytes) / 2**30
        print(f"[{arch_name} × {shape_name} × {mesh_name}] compile {t_compile:.1f}s | "
              f"args+temp {hbm_gib:.2f} GiB/dev | "
              f"compute {terms['compute_s']*1e3:.2f}ms "
              f"memory {terms['memory_s']*1e3:.2f}ms "
              f"collective {terms['collective_s']*1e3:.2f}ms "
              f"-> {terms['dominant']} | roofline {row['roofline_fraction']:.2%}",
              flush=True)
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="directory for JSON rows")
    args = ap.parse_args()

    archs = sorted(ARCHS) if (args.all or args.arch is None) else [args.arch]
    shapes = sorted(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    rows = []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        mesh_name = "2x16x16" if multi else "16x16"
        for a in archs:
            for s in shapes:
                try:
                    row = run_cell(a, s, mesh, mesh_name)
                except Exception as e:  # noqa: BLE001 — report, keep going
                    row = {"arch": a, "shape": s, "mesh": mesh_name,
                           "status": "error", "error": repr(e)[:500]}
                    print(f"[{a} × {s} × {mesh_name}] ERROR: {e}", flush=True)
                rows.append(row)
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    fn = f"{row['arch']}_{row['shape']}_{row['mesh']}.json"
                    with open(os.path.join(args.out, fn), "w") as f:
                        json.dump(row, f, indent=1, default=str)
    n_ok = sum(r["status"] == "ok" for r in rows)
    n_skip = sum(r["status"] == "skipped" for r in rows)
    n_err = len(rows) - n_ok - n_skip
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped (documented), {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

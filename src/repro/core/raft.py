"""Pure Raft replication + elections; consistency is a pluggable policy.

:class:`Node` implements only the replication substrate — log append,
AppendEntries/RequestVote, commit counting, elections, membership changes.
Every *consistency* decision (how reads are served, whether commits or
votes must wait, lease upkeep) is delegated to a
:class:`repro.consistency.ConsistencyPolicy` selected by
``RaftParams.read_mode``: LeaseGuard's commit gate and limbo region
(paper §3, Fig. 2), Ongaro leases ([41] §6.4.1), quorum reads, ReadIndex
batching, follower reads, and inconsistent reads each live in their own
module under ``repro.consistency``.

The policy hook points in this file:

* ``_handle_vote``         -> ``policy.gate_vote``
* ``_become_leader``       -> ``policy.on_become_leader`` + ``maintenance_task``
* ``_replicate`` ack       -> ``policy.on_append_response``
* ``_try_advance_commit``  -> ``policy.gate_commit`` / ``on_commit_blocked``
* ``_apply_committed``     -> ``policy.on_commit_advanced``
* ``client_write``         -> ``policy.gate_write``
* ``client_read``          -> ``policy.gate_read``
* unknown RPC types        -> ``policy.on_message``
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from .clock import BoundedClock, TimeInterval
from .network import Network
from .params import RaftParams
from .prob import PRNG
from .simulate import Condition, EventLoop, Future, TimeoutError_, wait_for

NOOP = "__noop__"
END_LEASE = "__end_lease__"
CONFIG = "__config__"          # single-node membership change (paper §4.4)


@dataclass(slots=True)
class LogEntry:
    term: int
    key: str                       # NOOP / END_LEASE for control entries
    value: Any
    interval: TimeInterval         # intervalNow() on the writing leader
    execution_ts: Optional[float] = None  # true time committed+applied on leader
    checksum: Optional[int] = None  # content checksum (RaftParams.entry_checksums)

    @property
    def is_control(self) -> bool:
        return self.key in (NOOP, END_LEASE, CONFIG)


# ---------------------------------------------------------------- messages
@dataclass(slots=True)
class RequestVote:
    term: int
    candidate: int
    last_log_index: int
    last_log_term: int


@dataclass(slots=True)
class VoteReply:
    term: int
    granted: bool


@dataclass(slots=True)
class AppendEntries:
    term: int
    leader: int
    prev_index: int
    prev_term: int
    entries: list
    leader_commit: int
    checksum: Optional[int] = None  # end-to-end digest (entry_checksums)


@dataclass(slots=True)
class PreVoteRequest:
    """Trial vote for ``term`` (= candidate's term + 1) that bumps NO
    term anywhere (Raft thesis §9.6): the candidate only campaigns for
    real once a majority signals it would win."""
    term: int
    candidate: int
    last_log_index: int
    last_log_term: int


@dataclass(slots=True)
class PreVoteReply:
    term: int
    granted: bool


def entry_checksum(term: int, key: str, value: Any) -> int:
    """Content checksum for one log entry (stable across replicas)."""
    return zlib.crc32(repr((term, key, value)).encode())


def append_digest(msg: "AppendEntries") -> int:
    """End-to-end digest over an AppendEntries' header fields and its
    entries' checksums — any in-flight field mutation breaks it."""
    return zlib.crc32(repr(
        (msg.term, msg.leader, msg.prev_index, msg.prev_term,
         msg.leader_commit, tuple(e.checksum for e in msg.entries))
    ).encode())


@dataclass(slots=True)
class AppendEntriesReply:
    term: int
    success: bool
    match_index: int


class WriteResult:
    __slots__ = ("ok", "error", "entry")

    def __init__(self, ok: bool, error: str = "",
                 entry: Optional["LogEntry"] = None) -> None:
        self.ok = ok
        self.error = error
        # The appended LogEntry object (shared across replicas in the sim):
        # its ``execution_ts`` is set iff/when the write actually commits,
        # which the omniscient checker uses to resolve ambiguous failures.
        self.entry = entry


class ReadResult:
    __slots__ = ("ok", "value", "error", "execution_ts")

    def __init__(self, ok: bool, value: Any = None, error: str = "",
                 execution_ts: float = 0.0) -> None:
        self.ok = ok
        self.value = value
        self.error = error
        self.execution_ts = execution_ts


_SENTINEL = LogEntry(term=0, key=NOOP, value=None,
                     interval=TimeInterval(-1e18, -1e18))


# ------------------------------------------------- membership config codec
def encode_config(voters, learners=()) -> object:
    """CONFIG entry value. Voter-only configs keep the legacy encoding (a
    sorted id list) so old logs and artifacts replay unchanged; configs
    with learners use ``{"voters": [...], "learners": [...]}``."""
    if learners:
        return {"voters": sorted(voters), "learners": sorted(learners)}
    return sorted(voters)


def parse_config(value) -> tuple[set, set]:
    """(voters, learners) from either CONFIG encoding."""
    if isinstance(value, dict):
        return set(value["voters"]), set(value["learners"])
    return set(value), set()


class Node:
    __slots__ = (
        "id", "loop", "net", "clock", "prng", "p", "config", "learners",
        "_seed_config", "_seed_learners", "_forced_learner", "on_leader",
        "term", "voted_for", "log", "state", "commit_index", "last_applied",
        "data", "alive", "next_index", "match_index",
        "last_index_at_election", "leader_hint", "_leader_epoch",
        "_last_heartbeat", "_cond", "_new_entries", "policy",
        "freeze_commit_broadcast", "_frozen_commit", "_timer_gen",
        "_election_sleep", "_last_peer_ack", "_backoff_fails",
        "_backoff_sleep", "elections_started", "prevote_rounds",
        "leader_evictions", "healthy_evictions", "quorum_step_downs",
        "checksum_drops", "_trace_ctx",
    )

    def __init__(self, node_id: int, loop: EventLoop, net: Network,
                 clock: BoundedClock, prng: PRNG, params: RaftParams,
                 peers: list[int],
                 on_leader: Optional[Callable[[int, int], None]] = None,
                 learners: Optional[list[int]] = None) -> None:
        self.id = node_id
        self.loop = loop
        self.net = net
        self.clock = clock
        self.prng = prng
        self.p = params
        # membership: mutated only via CONFIG log entries (paper §4.4
        # single-node changes — overlapping majorities preserve Leader
        # Completeness, on which the lease argument rests). ``config`` is
        # the VOTER set; ``learners`` receive AppendEntries and apply
        # state but are excluded from majorities, withhold votes, and
        # never start elections.
        self.config: set[int] = set(peers)
        self.learners: set[int] = set(learners or ())
        # the deployment-time config, used when truncation (or disk loss)
        # leaves a log with no surviving CONFIG entry
        self._seed_config: set[int] = set(self.config)
        self._seed_learners: set[int] = set(self.learners)
        # a wiped node rejoining via the safe path acts as a learner even
        # while its (re-replicated) log prefix still lists it as a voter;
        # cleared once a CONFIG entry recording its learner role arrives
        self._forced_learner = False
        self.on_leader = on_leader

        # persistent state
        self.term = 0
        self.voted_for: Optional[int] = None
        self.log: list[LogEntry] = [_SENTINEL]

        # volatile state
        self.state = "follower"
        self.commit_index = 0
        self.last_applied = 0
        self.data: dict[str, list] = {}
        self.alive = True

        # leader state
        self.next_index: dict[int, int] = {}
        self.match_index: dict[int, int] = {}
        self.last_index_at_election = 0
        self.leader_hint: Optional[int] = None  # who we last heard leads
        self._leader_epoch = 0   # bumps every leadership change; stops stale tasks

        self._last_heartbeat = loop.now
        self._cond = Condition(loop)     # commit/apply/state changes
        self._new_entries = Condition(loop)
        # consistency layer: all lease/read/vote/commit-gating decisions are
        # delegated to the policy selected by params.read_mode. (Local import:
        # repro.consistency imports from this module.)
        from ..consistency import make_policy
        self.policy = make_policy(self)
        # fault injection: freeze the commitIndex the leader advertises so
        # followers replicate entries without learning they are committed —
        # used to engineer a large limbo region (paper §6.6 places 100
        # entries in the limbo region to stress the skewness experiment).
        self.freeze_commit_broadcast = False
        self._frozen_commit = 0

        # gray-failure resilience state. _last_peer_ack feeds CheckQuorum
        # (and the healthy-eviction counter) from every AppendEntries
        # reply; the backoff dicts pace per-peer retries when
        # replication_backoff is on. All maintained without PRNG draws.
        self._last_peer_ack: dict[int, float] = {}
        self._backoff_fails: dict[int, int] = {}
        self._backoff_sleep: dict[int, tuple] = {}   # peer -> (future, timer)
        # instrumentation for the gray matrix (term-inflation and
        # lease-churn evidence); counting never changes behavior
        self.elections_started = 0   # real (term-bumping) campaigns
        self.prevote_rounds = 0      # trial rounds issued
        self.leader_evictions = 0    # deposed by a higher term while leading
        self.healthy_evictions = 0   # ... while still reaching a quorum
        self.quorum_step_downs = 0   # voluntary CheckQuorum step-downs
        self.checksum_drops = 0      # corrupted AppendEntries dropped

        # flight recorder context: id of this node's latest role-transition
        # event (repro.obs) — the causal parent of everything it does.
        # None until the first role event, and always None when untraced.
        self._trace_ctx: Optional[int] = None

        # bumps on every crash/restart so a timer task from a previous
        # incarnation exits instead of running alongside the new one
        self._timer_gen = 0
        # the election timer's parked (future, timer); lazy-cancelled on
        # crash/restart so a dead generation exits immediately instead of
        # leaving its wakeup in the heap until the old deadline
        self._election_sleep: Optional[tuple] = None

        net.register(node_id, self._on_message)
        loop.create_task(self._election_timer(self._timer_gen))

    # ------------------------------------------------------------- helpers
    @property
    def last_log_index(self) -> int:
        return len(self.log) - 1

    @property
    def peers(self) -> list[int]:
        """Voting peers: election + quorum-round targets."""
        return [p for p in self.config if p != self.id]

    @property
    def replication_peers(self) -> list[int]:
        """Everyone the leader replicates to: voters AND learners."""
        return [p for p in self.config if p != self.id] + \
            [p for p in self.learners if p != self.id]

    def majority(self) -> int:
        """Quorum size over VOTERS only — learners never count."""
        return len(self.config) // 2 + 1

    def is_learner(self) -> bool:
        """Non-voting: in the learner set, forced by a safe disk-loss
        rejoin, or simply not (yet / any longer) a voting member."""
        return self._forced_learner or self.id in self.learners \
            or self.id not in self.config

    def _refresh_config(self) -> None:
        """Adopt the newest CONFIG entry in the log (Raft uses the latest
        config as soon as it is appended, not committed). If conflict
        truncation (or a disk wipe) removed EVERY config entry, fall back
        to the seed config — silently keeping the truncated-away
        membership would count majorities against a config no surviving
        log supports."""
        for i in range(self.last_log_index, 0, -1):
            if self.log[i].key == CONFIG:
                self._adopt_config(*parse_config(self.log[i].value))
                return
        self._adopt_config(set(self._seed_config), set(self._seed_learners))

    def _adopt_config(self, voters: set, learners: set = frozenset()) -> None:
        old = self.config | self.learners
        new = set(voters) | set(learners)
        self.config = set(voters)
        self.learners = set(learners)
        if self.state == "leader":
            # prune replication bookkeeping for removed members — without
            # this, next/match entries (and their heartbeat loops, via the
            # membership check in _replicate) leak across reconfigurations
            for p in old - new:
                self.next_index.pop(p, None)
                self.match_index.pop(p, None)
                # a backoff park pending for a pruned peer must be
                # cancelled/reaped, not left to fire into next_index for
                # a ghost peer (its _replicate task wakes, sees the peer
                # gone, and exits)
                self._backoff_fails.pop(p, None)
                self._wake_backoff(p)
            for p in new - old:
                if p not in self.next_index and p != self.id:
                    self.next_index[p] = self.last_log_index + 1
                    self.match_index[p] = 0
                    self.loop.create_task(
                        self._replicate(p, self._leader_epoch))

    def _signal(self) -> None:
        self._cond.notify_all()

    def is_leader(self) -> bool:
        return self.state == "leader" and self.alive

    # compatibility shims: mechanism state lives on the policy
    @property
    def limbo_keys(self) -> set:
        return getattr(self.policy, "limbo_keys", set())

    def _commit_gate_blocked(self) -> bool:
        return self.policy.gate_commit()

    # ------------------------------------------------------ crash / restart
    def crash(self) -> None:
        tr = self.loop.tracer
        if tr is not None:
            self._trace_ctx = tr.emit("role", node=self.id, term=self.term,
                                      parent=self._trace_ctx, role="down",
                                      reason="crash")
        self.alive = False
        self.state = "follower"
        self._leader_epoch += 1
        self._timer_gen += 1
        self.net.set_down(self.id, True)
        self._wake_election_timer()
        for p in list(self._backoff_sleep):
            self._wake_backoff(p)       # parked retries exit via the guard
        self._backoff_fails.clear()
        self._signal()

    def _wake_election_timer(self) -> None:
        """Lazy-cancel the parked election timer: its heap entry is marked
        dead (reaped at pop, never dispatched) and the waiting generation
        is woken now — it re-checks its guard, sees the generation bump,
        and exits instead of lingering until the old deadline. No PRNG
        draw happens on the dead path, so replay is unaffected."""
        parked = self._election_sleep
        if parked is not None:
            f, timer = parked
            timer.cancel()
            if not f.done():
                f.set_result(None)

    def _wake_backoff(self, peer: int) -> None:
        """Lazy-cancel a parked replication-backoff sleep (same scheme as
        the election timer): the heap entry is reaped, the waiting
        _replicate task wakes now, re-checks membership, and exits."""
        parked = self._backoff_sleep.pop(peer, None)
        if parked is not None:
            f, timer = parked
            timer.cancel()
            if not f.done():
                f.set_result(None)

    async def _backoff_park(self, peer: int, delay: float) -> None:
        f = Future(self.loop)
        timer = self.loop.call_later_cancelable(delay, f._wake)
        self._backoff_sleep[peer] = (f, timer)
        await f
        if self._backoff_sleep.get(peer, (None,))[0] is f:
            del self._backoff_sleep[peer]

    def restart(self, wipe_disk: bool = False,
                rejoin_as_learner: bool = False) -> None:
        """Come back from a crash with persistent state (term, voted_for,
        log) intact. With ``wipe_disk`` the persistent state is ALSO lost —
        the node rejoins as if freshly installed. That exceeds Raft's fault
        model (a wiped voter can re-vote in a term and break Leader
        Completeness), which is exactly why the nemesis engine offers it:
        the linearizability matrix classifies it as an *unsafe* fault.
        The static membership config is assumed to survive reinstalls (it
        lives in deployment config, not the Raft log).

        ``rejoin_as_learner`` is the SAFE wipe path (ROADMAP item): the
        node comes back refusing to vote or campaign — regardless of what
        stale log prefixes claim — until a CONFIG entry recording its
        learner demotion reaches it; the leader then catches it up and
        auto-promotes it via an ordinary CONFIG entry."""
        if wipe_disk:
            self.term = 0
            self.voted_for = None
            self.log = [_SENTINEL]
            self._forced_learner = rejoin_as_learner
        self.alive = True
        self.state = "follower"
        self.commit_index = 0
        self.last_applied = 0
        self.data = {}
        self.leader_hint = None
        self._last_heartbeat = self.loop.now
        self._refresh_config()       # membership may have changed on disk
        # policy state is process-volatile: a restarted node starts fresh
        from ..consistency import make_policy
        self.policy = make_policy(self)
        self.net.set_down(self.id, False)
        tr = self.loop.tracer
        if tr is not None:
            self._trace_ctx = tr.emit(
                "role", node=self.id, term=self.term, parent=self._trace_ctx,
                role="follower",
                reason="restart_wiped" if wipe_disk else "restart")
        self._timer_gen += 1
        self._wake_election_timer()   # reap any parked prior-gen wakeup
        self.loop.create_task(self._election_timer(self._timer_gen))

    # --------------------------------------------------------- RPC handler
    def _on_message(self, src: int, msg: Any) -> Any:
        if not self.alive:
            return None
        if isinstance(msg, RequestVote):
            return self._handle_vote(src, msg)
        if isinstance(msg, AppendEntries):
            return self._handle_append(src, msg)
        if isinstance(msg, PreVoteRequest):
            return self._handle_prevote(src, msg)
        return self.policy.on_message(src, msg)

    def _step_down(self, term: int, count_eviction: bool = True) -> None:
        tr = self.loop.tracer
        if term > self.term:
            if tr is not None:
                tr.emit("term_bump", node=self.id, term=term,
                        parent=self._trace_ctx, prev=self.term)
            self.term = term
            self.voted_for = None
        if self.state != "follower":
            was_leader = self.state == "leader"
            if was_leader and count_eviction:
                # deposed by a higher term; "healthy" if we could still
                # reach a quorum — the disruptive-election signature
                # PreVote/CheckQuorum exist to prevent
                self.leader_evictions += 1
                if self._quorum_connected():
                    self.healthy_evictions += 1
            self.state = "follower"
            self._leader_epoch += 1
            if tr is not None:
                reason = ("check_quorum" if not count_eviction
                          else "deposed" if was_leader else "higher_term")
                self._trace_ctx = tr.emit(
                    "role", node=self.id, term=self.term,
                    parent=self._trace_ctx, role="follower", reason=reason)
        self._signal()

    def _quorum_connected(self) -> bool:
        """Did we hear from a voting majority (incl. ourselves) within
        the last election timeout? Fed by every AppendEntries reply."""
        horizon = self.loop.now - self.p.election_timeout
        live = 1 + sum(1 for p in self.peers
                       if self._last_peer_ack.get(p, float("-inf")) >= horizon)
        return live >= self.majority()

    def _handle_vote(self, src: int, msg: RequestVote) -> VoteReply:
        if msg.term > self.term:
            self._step_down(msg.term)
        granted = False
        if self.is_learner():
            # non-voting: a learner (or a wiped node on the safe rejoin
            # path) must never contribute to an election quorum before its
            # promotion CONFIG entry — Leader Completeness rests on it
            return VoteReply(self.term, False)
        if msg.term == self.term and self.voted_for in (None, msg.candidate):
            up_to_date = (msg.last_log_term, msg.last_log_index) >= (
                self.log[-1].term, self.last_log_index)
            # e.g. Ongaro leases withhold votes within ET of hearing from a
            # leader; LeaseGuard deliberately does not delay elections.
            vote_blocked = self.policy.gate_vote(msg)
            if up_to_date and not vote_blocked:
                granted = True
                self.voted_for = msg.candidate
                self._last_heartbeat = self.loop.now
        tr = self.loop.tracer
        if tr is not None:
            tr.emit("vote", node=self.id, term=self.term,
                    parent=self._trace_ctx, candidate=msg.candidate,
                    granted=granted, prevote=False)
        return VoteReply(self.term, granted)

    def _handle_prevote(self, src: int, msg: PreVoteRequest) -> PreVoteReply:
        """Trial vote (thesis §9.6): NEVER bumps our term, never sets
        voted_for, never resets the election timer — purely advisory.
        Granted only if the candidate's log is up-to-date AND we have not
        heard from a live leader within an election timeout, so a healed
        flapper cannot depose a healthy lease-holding leader, and a
        partitioned one cannot inflate terms at all."""
        granted = False
        if not self.is_learner() and msg.term > self.term:
            up_to_date = (msg.last_log_term, msg.last_log_index) >= (
                self.log[-1].term, self.last_log_index)
            heard_leader = self.state == "leader" or (
                self.leader_hint is not None
                and self.loop.now - self._last_heartbeat
                < self.p.election_timeout)
            granted = up_to_date and not heard_leader
        tr = self.loop.tracer
        if tr is not None:
            tr.emit("vote", node=self.id, term=self.term,
                    parent=self._trace_ctx, candidate=msg.candidate,
                    granted=granted, prevote=True)
        return PreVoteReply(self.term, granted)

    def _handle_append(self, src: int,
                       msg: AppendEntries) -> Optional[AppendEntriesReply]:
        if self.p.entry_checksums and (
                msg.checksum is None or msg.checksum != append_digest(msg)
                or any(e.checksum != entry_checksum(e.term, e.key, e.value)
                       for e in msg.entries)):
            # end-to-end integrity failed: detected-and-dropped before any
            # state (even our term) is touched. No reply — the sender's
            # RPC times out and retries with a fresh transmission.
            self.checksum_drops += 1
            return None
        if msg.term < self.term:
            return AppendEntriesReply(self.term, False, 0)
        if msg.term > self.term or self.state != "follower":
            self._step_down(msg.term)
        self._last_heartbeat = self.loop.now
        self.leader_hint = msg.leader
        # log consistency check; the failure reply carries our last log
        # index so the leader can clamp a match_index that exceeds our
        # actual log (only possible after a disk wipe — without it the
        # clamp is a no-op, since a matched prefix never shrinks within
        # the leader's term)
        if msg.prev_index < 0 or msg.prev_index > self.last_log_index or \
                self.log[msg.prev_index].term != msg.prev_term:
            # (negative prev_index is only reachable via in-flight field
            # corruption; honest leaders never send one)
            return AppendEntriesReply(self.term, False, self.last_log_index)
        # append / resolve conflicts
        idx = msg.prev_index
        config_touched = False
        for e in msg.entries:
            idx += 1
            if idx <= self.last_log_index:
                if self.log[idx].term != e.term:
                    config_touched |= any(x.key == CONFIG
                                          for x in self.log[idx:])
                    del self.log[idx:]          # truncate conflicting suffix
                    # impossible honestly (committed prefixes never
                    # truncate), but corruption of leader_commit with
                    # checksums off can leave these pointing past the
                    # log; clamp so the checker — not an IndexError —
                    # reports the resulting divergence
                    if self.commit_index > self.last_log_index:
                        self.commit_index = self.last_log_index
                    if self.last_applied > self.last_log_index:
                        self.last_applied = self.last_log_index
                    self.log.append(e)
                    config_touched |= e.key == CONFIG
            else:
                self.log.append(e)
                config_touched |= e.key == CONFIG
        if config_touched:
            self._refresh_config()
        match = msg.prev_index + len(msg.entries)
        if self._forced_learner and 0 < msg.leader_commit <= self.last_log_index:
            # a wiped node's vote is safe again exactly when its (prefix-
            # matched) log covers the cluster commit point: from here on it
            # only votes for candidates at least as complete as that log.
            # Content-based tests (e.g. "saw a CONFIG demoting me") cannot
            # distinguish a pre-wipe learner stint from the post-wipe
            # demotion, so catch-up is the only sound clearing condition.
            self._forced_learner = False
        if msg.leader_commit > self.commit_index:
            self.commit_index = min(msg.leader_commit, self.last_log_index)
            self._apply_committed()
        return AppendEntriesReply(self.term, True, match)

    # ------------------------------------------------------------ elections
    async def _election_timer(self, gen: int) -> None:
        while self.alive and self._timer_gen == gen:
            timeout = self.p.election_timeout + self.prng.uniform(
                0.0, self.p.election_jitter)
            deadline = self._last_heartbeat + timeout
            if self.loop.now < deadline:
                # cancelable sleep: crash/restart reaps the heap entry and
                # wakes this generation immediately (it then exits)
                f = Future(self.loop)
                timer = self.loop.call_later_cancelable(
                    deadline - self.loop.now, f._wake)
                self._election_sleep = (f, timer)
                await f
                self._election_sleep = None
                continue
            if self.state == "leader" or self.is_learner():
                if self.state == "leader" and self.p.check_quorum \
                        and not self._quorum_connected():
                    # CheckQuorum: no word from a voting majority within
                    # an election timeout — step down and stop serving
                    # the lease instead of riding out a doomed lease
                    # window in which every read/write can only time out
                    self.quorum_step_downs += 1
                    self.policy.on_quorum_lost()
                    self._step_down(self.term, count_eviction=False)
                # learners never start elections; they just keep waiting
                self._last_heartbeat = self.loop.now
                continue
            await self._run_for_election()

    async def _prevote_round(self) -> bool:
        """One PreVote round: poll the voters with a trial ballot for
        ``term + 1`` without bumping any term. True = a majority signals
        the real campaign would win. While partitioned this keeps
        failing, so a flapping node's term never inflates."""
        self.prevote_rounds += 1
        tr = self.loop.tracer
        if tr is not None:
            tr.emit("election", node=self.id, term=self.term + 1,
                    parent=self._trace_ctx, kind="prevote")
        term0 = self.term
        msg = PreVoteRequest(self.term + 1, self.id, self.last_log_index,
                             self.log[-1].term)
        grants = 1
        futs = [self.net.call(self.id, p, msg) for p in self.peers]
        for f in futs:
            try:
                reply: PreVoteReply = await wait_for(f, self.p.rpc_timeout)
            except TimeoutError_:
                continue
            # abort if circumstances changed mid-round (a vote was
            # granted, a higher term arrived); a same-term heartbeat
            # keeps the round alive — peers hearing that leader refuse
            # anyway. (state may legitimately be "candidate" here: a
            # node whose previous real election failed retries.)
            if not self.alive or self.term != term0 \
                    or self.state == "leader":
                return False
            if reply.term > self.term:
                self._step_down(reply.term)
                return False
            if reply.granted:
                grants += 1
            if grants >= self.majority():
                return True
        return grants >= self.majority()

    async def _run_for_election(self) -> None:
        if self.p.prevote:
            self._last_heartbeat = self.loop.now  # full timeout before retry
            if not await self._prevote_round():
                return                            # stay a quiet follower
        self.term += 1
        self.elections_started += 1
        term = self.term
        self.state = "candidate"
        self.voted_for = self.id
        self._last_heartbeat = self.loop.now
        tr = self.loop.tracer
        if tr is not None:
            self._trace_ctx = tr.emit(
                "role", node=self.id, term=term, parent=self._trace_ctx,
                role="candidate", reason="election_timeout")
            tr.emit("election", node=self.id, term=term,
                    parent=self._trace_ctx, kind="campaign")
        msg = RequestVote(term, self.id, self.last_log_index, self.log[-1].term)
        votes = 1
        futs = [self.net.call(self.id, p, msg) for p in self.peers]
        for f in futs:
            try:
                reply: VoteReply = await wait_for(f, self.p.rpc_timeout)
            except TimeoutError_:
                continue
            if not self.alive or self.state != "candidate" or self.term != term:
                return
            if reply.term > self.term:
                self._step_down(reply.term)
                return
            if reply.granted:
                votes += 1
            if votes >= self.majority():
                self._become_leader()
                return

    def _become_leader(self) -> None:
        self.state = "leader"
        self._leader_epoch += 1
        epoch = self._leader_epoch
        self.next_index = {p: self.last_log_index + 1
                           for p in self.replication_peers}
        self.match_index = {p: 0 for p in self.replication_peers}
        # CheckQuorum grace: a fresh leader gets one full election
        # timeout before connectivity is judged
        self._last_peer_ack = {p: self.loop.now for p in self.peers}
        self._backoff_fails.clear()
        self.last_index_at_election = self.last_log_index
        self.leader_hint = self.id
        tr = self.loop.tracer
        if tr is not None:
            self._trace_ctx = tr.emit(
                "role", node=self.id, term=self.term, parent=self._trace_ctx,
                role="leader", reason="won_election")
        self.policy.on_become_leader()
        if self.p.noop_on_election:
            self._append_local(NOOP, None)
        for p in self.replication_peers:
            self.loop.create_task(self._replicate(p, epoch))
        self.loop.create_task(self.policy.maintenance_task(epoch))
        if self.on_leader is not None:
            self.on_leader(self.id, self.term)
        self._signal()

    # ------------------------------------------------------------ leader ops
    def _append_local(self, key: str, value: Any) -> int:
        entry = LogEntry(self.term, key, value, self.clock.interval_now())
        if self.p.entry_checksums:
            entry.checksum = entry_checksum(entry.term, entry.key,
                                            entry.value)
        self.log.append(entry)
        if key == CONFIG:
            self._adopt_config(*parse_config(value))
        self._new_entries.notify_all()
        self._try_advance_commit()   # single-node replica sets commit locally
        return self.last_log_index

    def _make_append(self, prev_index: int, entries: list,
                     commit: int) -> AppendEntries:
        """Build an AppendEntries, stamping the end-to-end digest when
        ``entry_checksums`` is on (every sender — replication loop and
        policy barriers alike — must go through here)."""
        msg = AppendEntries(self.term, self.id, prev_index,
                            self.log[prev_index].term, entries, commit)
        if self.p.entry_checksums:
            msg.checksum = append_digest(msg)
        return msg

    async def _replicate(self, peer: int, epoch: int) -> None:
        """Per-follower replication + heartbeat loop (voters AND learners)."""
        while self.alive and self.state == "leader" \
                and self._leader_epoch == epoch \
                and (peer in self.config or peer in self.learners):
            ni = self.next_index[peer]
            entries = self.log[ni: ni + self.p.batch_max_entries]
            prev = ni - 1
            if self.freeze_commit_broadcast:
                advertised_commit = min(self._frozen_commit, self.commit_index)
            else:
                advertised_commit = self.commit_index
            msg = self._make_append(prev, list(entries), advertised_commit)
            start = self.loop.now
            size = 256 + sum(64 + (len(e.value) if isinstance(e.value, (bytes, str))
                                   else 8) for e in entries)
            try:
                reply: AppendEntriesReply = await wait_for(
                    self.net.call(self.id, peer, msg, size=size),
                    self.p.rpc_timeout)
            except TimeoutError_:
                if self.p.replication_backoff:
                    # capped exponential backoff + jitter instead of the
                    # fixed rpc_timeout hot-loop against a slow/dead peer
                    fails = self._backoff_fails.get(peer, 0) + 1
                    self._backoff_fails[peer] = fails
                    delay = min(self.p.backoff_max,
                                self.p.backoff_base * (1 << (fails - 1)))
                    delay *= 1.0 + self.prng.random()
                    await self._backoff_park(peer, delay)
                    if peer not in self.next_index:
                        return    # pruned from the config while parked
                continue
            self._last_peer_ack[peer] = self.loop.now
            self._backoff_fails.pop(peer, None)
            if not self.alive or self.state != "leader" or self._leader_epoch != epoch:
                return
            if reply.term > self.term:
                self._step_down(reply.term)
                return
            if peer not in self.next_index:
                return            # removed from the config during the RPC
            if reply.success:
                self.policy.on_append_response(peer, start)
                if reply.match_index > self.match_index[peer]:
                    self.match_index[peer] = reply.match_index
                self.next_index[peer] = reply.match_index + 1
                self._try_advance_commit()
                if peer in self.learners and self.p.auto_promote_learners \
                        and reply.match_index >= self.commit_index:
                    # caught up to everything committed: promote to voter
                    # via an ordinary single-node CONFIG entry
                    self._maybe_promote_learner(peer)
                if self.next_index.get(peer, 0) > self.last_log_index:
                    # up to date: wait for new entries or heartbeat tick
                    await self._wait_new_entries(self.p.heartbeat_interval)
            else:
                # the reply's match_index is the follower's last log index:
                # clamp our record if its log REGRESSED (disk wipe) so a
                # lost log is never counted toward a commit majority
                if reply.match_index < self.match_index[peer]:
                    self.match_index[peer] = reply.match_index
                self.next_index[peer] = max(1, self.next_index[peer] - 1)

    async def _wait_new_entries(self, timeout: float) -> None:
        """Wait until new entries are appended, or the heartbeat tick fires."""
        await self._new_entries.wait(timeout)

    # -- commit counting (gated by the policy, e.g. LeaseGuard Fig. 2) ------
    def _try_advance_commit(self) -> None:
        if self.state != "leader" or not self.alive:
            return
        if self.policy.gate_commit():
            self.policy.on_commit_blocked()
            return
        matches = sorted([v for p, v in self.match_index.items()
                          if p in self.config] + [self.last_log_index],
                         reverse=True)
        m = matches[self.majority() - 1]
        # standard Raft: only count-commit entries of the current term
        while m > self.commit_index and self.log[m].term != self.term:
            m -= 1
        if m > self.commit_index:
            self.commit_index = m
            self._apply_committed()

    def _apply_committed(self) -> None:
        advanced = False
        while self.last_applied < self.commit_index:
            self.last_applied += 1
            e = self.log[self.last_applied]
            if not e.is_control:
                self.data.setdefault(e.key, []).append(e.value)
            if self.state == "leader" and e.execution_ts is None:
                e.execution_ts = self.loop.now   # commit-on-leader time (§6.2)
            advanced = True
        if advanced:
            if self.state == "leader":
                tr = self.loop.tracer
                if tr is not None:
                    tr.emit("commit", node=self.id, term=self.term,
                            parent=self._trace_ctx, index=self.commit_index)
                self.policy.on_commit_advanced()
            self._signal()

    def _reconfig_in_progress(self) -> bool:
        """One reconfiguration at a time: any uncommitted CONFIG blocks."""
        for i in range(self.last_log_index, self.commit_index, -1):
            if self.log[i].key == CONFIG:
                return True
        return False

    def _maybe_promote_learner(self, peer: int) -> None:
        """Auto-promotion (driven from the replication loop): once a
        learner's acked match_index covers the leader's commit index, a
        CONFIG entry moves it into the voter set."""
        if self.state != "leader" or not self.alive \
                or peer not in self.learners or self._reconfig_in_progress():
            return
        self._append_local(CONFIG, encode_config(self.config | {peer},
                                                 self.learners - {peer}))

    async def change_membership(self, new_config: set,
                                learners: Optional[set] = None) -> WriteResult:
        """Single-node reconfiguration (paper §4.4): add or remove ONE
        node, add/remove a learner, or change one node's role
        (learner⇄voter). The CONFIG entry is an ordinary log entry — it
        carries a clock interval, extends the lease, and obeys the commit
        gate, so all LeaseGuard guarantees hold across the change
        (overlapping majorities over the VOTER set preserve Leader
        Completeness; learner-set changes never move a quorum).

        ``learners=None`` keeps the current learner set minus any node
        being promoted into ``new_config`` — so the legacy voter-only call
        shape both adds fresh voters and promotes learners."""
        if not self.is_leader():
            return WriteResult(False, "not_leader")
        new_voters = set(new_config)
        new_learners = (self.learners - new_voters if learners is None
                        else set(learners))
        if new_voters & new_learners:
            return WriteResult(False, "voter_learner_overlap")
        affected = (new_voters ^ self.config) | (new_learners ^ self.learners)
        if len(affected) != 1:
            return WriteResult(False, "only_single_node_changes")
        if self.id not in new_voters:
            return WriteResult(False, "cannot_remove_leader")
        if self._reconfig_in_progress():
            return WriteResult(False, "reconfig_in_progress")
        index = self._append_local(CONFIG,
                                   encode_config(new_voters, new_learners))
        entry = self.log[index]
        deadline = self.loop.now + self.p.write_timeout
        while self.alive:
            if self.last_applied >= index and len(self.log) > index \
                    and self.log[index] is entry:
                return WriteResult(True, entry=entry)
            if self.state != "leader" or self.loop.now >= deadline:
                return WriteResult(False, "failed", entry=entry)
            await self._cond_wait(deadline)
        return WriteResult(False, "crashed", entry=entry)

    def freeze_commits(self) -> None:
        """Fault injection: stop advertising commitIndex advances."""
        self._frozen_commit = self.commit_index
        self.freeze_commit_broadcast = True

    def relinquish_lease(self) -> None:
        """Planned handover (§5.1): commit an end-lease entry, then step down."""
        if self.is_leader():
            tr = self.loop.tracer
            if tr is not None:
                tr.emit("lease", node=self.id, term=self.term,
                        parent=self._trace_ctx, op="relinquish")
            self._append_local(END_LEASE, None)

    # ---------------------------------------------------------- client API
    async def client_write(self, key: str, value: Any) -> WriteResult:
        tr = self.loop.tracer
        if tr is None:
            return await self._client_write(key, value)
        # traced path: same single awaited coroutine, so scheduling (and
        # with it every PRNG draw) is identical to the untraced path
        sid = tr.emit("write", node=self.id, term=self.term,
                      parent=self._trace_ctx, op="start", key=key)
        res = await self._client_write(key, value)
        if res.ok:
            tr.emit("write", node=self.id, term=self.term, parent=sid,
                    op="done", key=key)
        else:
            tr.emit("write", node=self.id, term=self.term, parent=sid,
                    op="fail", key=key, error=res.error)
        return res

    async def _client_write(self, key: str, value: Any) -> WriteResult:
        if not self.is_leader():
            return WriteResult(False, "not_leader")
        err = self.policy.gate_write()
        if err:
            return WriteResult(False, err)
        term0 = self.term
        index = self._append_local(key, value)
        entry = self.log[index]
        deadline = self.loop.now + self.p.write_timeout
        while self.alive:
            if self.last_applied >= index:
                if len(self.log) > index and self.log[index] is entry:
                    return WriteResult(True, entry=entry)
                return WriteResult(False, "not_leader", entry=entry)  # lost
            if self.state != "leader" or self.term != term0:
                return WriteResult(False, "not_leader", entry=entry)  # unknown
            if self.loop.now >= deadline:
                return WriteResult(False, "timeout", entry=entry)
            await self._cond_wait(deadline)
        return WriteResult(False, "crashed", entry=entry)

    async def client_read(self, key: str) -> ReadResult:
        tr = self.loop.tracer
        if tr is None:
            return await self.policy.gate_read(key)
        t0 = self.loop.now
        sid = tr.emit("read", node=self.id, term=self.term,
                      parent=self._trace_ctx, op="start", key=key)
        res = await self.policy.gate_read(key)
        if res.ok:
            tr.emit("read", node=self.id, term=self.term, parent=sid,
                    op="done", key=key, stall=self.loop.now - t0)
        else:
            tr.emit("read", node=self.id, term=self.term, parent=sid,
                    op="fail", key=key, error=res.error,
                    stall=self.loop.now - t0)
        return res

    async def _cond_wait(self, deadline: float) -> None:
        await self._cond.wait(max(0.0, deadline - self.loop.now) + 1e-9)

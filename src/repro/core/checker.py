"""Omniscient linearizability checker (paper §6.2).

The simulator knows the true time of every event. Each ``ListAppend``'s
``execution_ts`` is when the write was committed on the leader; each
``Read``'s is when it executed. Checking:

1. every successful op's execution time lies in ``[start_ts, end_ts]``
   (a failed-but-actually-committed append only needs ``exec >= start``);
2. sort by execution time — this IS the linearization (it respects real
   time by construction), so keys can be checked independently;
3. replay per-key append-only-list semantics: every successful read must
   observe exactly the list of preceding appends;
4. ties (identical execution times) are checked exactly: within a tie
   group the reads' observed lists must form a prefix chain extending the
   incoming state, using only that group's appends (equivalent to trying
   all orderings, but linear time);
5. a failed append with no execution time never took effect (the
   simulator is omniscient: any entry committed anywhere gets a commit
   timestamp), so the paper's two-way ambiguity collapses.

General linearizability checking is NP-complete [18]; omniscience makes
it tractable.
"""

from __future__ import annotations

from collections import defaultdict
from typing import Iterable

from .client import ClientLogEntry


class LinearizabilityError(AssertionError):
    pass


def check_linearizability(history: Iterable[ClientLogEntry]) -> int:
    """Raise LinearizabilityError on violation; return #ops checked."""
    per_key: dict[str, list[ClientLogEntry]] = defaultdict(list)
    n = 0
    for op in history:
        if op.op_type == "ListAppend":
            if op.success:
                if op.execution_ts is None:
                    raise LinearizabilityError(
                        f"acked append has no commit time: {op}")
                if not (op.start_ts <= op.execution_ts <= op.end_ts):
                    raise LinearizabilityError(
                        f"append executed outside [start, end]: {op}")
                per_key[op.key].append(op)
                n += 1
            elif op.execution_ts is not None:
                # failed at the client but actually committed
                if op.execution_ts < op.start_ts:
                    raise LinearizabilityError(
                        f"append committed before invocation: {op}")
                per_key[op.key].append(op)
                n += 1
        elif op.op_type == "Read" and op.success:
            if op.execution_ts is None or \
                    not (op.start_ts <= op.execution_ts <= op.end_ts):
                raise LinearizabilityError(
                    f"read executed outside [start, end]: {op}")
            per_key[op.key].append(op)
            n += 1
    for key, ops in per_key.items():
        _check_key(key, ops)
    return n


def _check_key(key: str, ops: list[ClientLogEntry]) -> None:
    ops.sort(key=lambda o: o.execution_ts)
    state: list = []
    i = 0
    while i < len(ops):
        # tie group: identical execution timestamps
        j = i
        ts = ops[i].execution_ts
        while j < len(ops) and ops[j].execution_ts == ts:
            j += 1
        group = ops[i:j]
        if len(group) == 1 and group[0].op_type == "Read":
            if list(group[0].value) != state:
                raise LinearizabilityError(
                    f"key {key}: read at t={ts} observed {group[0].value}, "
                    f"expected {state}")
        elif len(group) == 1:
            state.append(group[0].value)
        else:
            state = _check_tie_group(key, state, group)
        i = j


def _check_tie_group(key: str, state: list, group: list[ClientLogEntry]) -> list:
    appends = [o.value for o in group if o.op_type == "ListAppend"]
    reads = sorted((o for o in group if o.op_type == "Read"),
                   key=lambda o: len(o.value))
    # reads must form a prefix chain: state ⊑ r1 ⊑ r2 ⊑ ... using only this
    # group's appends for the extensions
    prev = list(state)
    used: list = []
    for r in reads:
        obs = list(r.value)
        if obs[:len(prev)] != prev or len(obs) < len(prev):
            raise LinearizabilityError(
                f"key {key}: tied read observed {obs}, incompatible with "
                f"{prev}")
        ext = obs[len(prev):]
        for v in ext:
            if v not in appends or v in used:
                raise LinearizabilityError(
                    f"key {key}: tied read observed unknown/duplicate "
                    f"append {v}")
            used.append(v)
        prev = obs
    final = list(prev) + [v for v in appends if v not in used]
    return final

"""Fig. 8: effect of workload skewness on read availability on the new
leader while it waits for its lease (inherited-lease reads + limbo region).

Setup mirrors §6.6: Zipf(a) over 1000 keys, a ∈ [0, 2]; a limbo region is
engineered by freezing the old leader's commitIndex broadcasts before the
crash (the paper places ~100 entries in the limbo region). Higher skew ⇒
hot keys are more likely to be limbo-affected ⇒ fewer reads permitted.
"""

from __future__ import annotations

from repro.core import RaftParams, SimParams, run_workload

from .common import freeze_then_crash_at


def run(quick: bool = False) -> list[dict]:
    skews = [0.0, 1.0, 2.0] if quick else [0.0, 0.5, 1.0, 1.5, 2.0]
    rows = []
    for a in skews:
        raft = RaftParams(election_timeout=0.5, election_jitter=0.1,
                          heartbeat_interval=0.05, lease_duration=1.5)
        sim = SimParams(seed=8, sim_duration=2.2 if quick else 3.0,
                        interarrival=1e-3 if quick else 300e-6,
                        write_fraction=1 / 3, zipf_a=a, n_keys=1000)
        # freeze commit broadcasts at 0.35s, crash at 0.6s: entries written
        # in [0.35, 0.6) land in the new leader's limbo region
        res = run_workload(raft, sim,
                           fault_script=freeze_then_crash_at(0.35, 0.6),
                           check=False, settle_time=1.5)
        t0 = min(op.start_ts for op in res.history)
        # wait window: post-election, pre-lease-expiry
        lo, hi = t0 + 1.3, t0 + 2.0
        ok = limbo = other_fail = 0
        for op in res.history:
            if op.op_type == "Read" and lo <= op.start_ts <= hi:
                if op.success:
                    ok += 1
                elif op.error == "limbo":
                    limbo += 1
                else:
                    other_fail += 1
        total = max(1, ok + limbo)
        rows.append({
            "zipf_a": a,
            "window_reads_ok": ok,
            "window_reads_limbo": limbo,
            "limbo_reject_rate": limbo / total,
        })
    return rows

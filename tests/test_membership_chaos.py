"""Membership churn under fire: scheduled add/promote/remove chaos and
the safe disk-loss rejoin, swept against the consistency-policy registry
with the linearizability oracle (property-based via the hypothesis stub
fallback)."""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fixed-example fallback
    from _hypothesis_stub import given, settings, st

from repro.core import (LinearizabilityError, RaftParams, ReadMode, SimParams,
                        check_linearizability, run_workload)
from repro.faults import (CrashRestart, MembershipChaos, DiskLossRejoin,
                          PartialPartition, Scenario, Window, build_scenario,
                          random_membership_scenario, safe_scenario_names)


def churn_run(mode, scenario, seed, *, follower_frac=0.0):
    raft = RaftParams(read_mode=mode, election_timeout=0.3,
                      election_jitter=0.1, heartbeat_interval=0.03,
                      lease_duration=0.6, rpc_timeout=0.15)
    sim = SimParams(seed=seed, sim_duration=1.2, interarrival=3e-3,
                    follower_read_fraction=follower_frac)
    if isinstance(scenario, str):
        scenario = build_scenario(scenario)
    return run_workload(raft, sim, fault_script=scenario.install,
                        check=False, settle_time=1.5)


def test_membership_scenarios_are_registered_safe():
    names = set(safe_scenario_names())
    assert {"membership_churn", "membership_churn_crash",
            "membership_churn_partition", "disk_loss_safe"} <= names


# ------------------------------------------------ named deterministic cases
@pytest.mark.parametrize("mode", [ReadMode.LEASEGUARD, ReadMode.READ_INDEX,
                                  ReadMode.QUORUM],
                         ids=["leaseguard", "readindex", "quorum"])
def test_learner_promotion_mid_partition(mode):
    """A learner joins and gets promoted while a partial partition is
    up — the CONFIG entries must still commit through real quorums."""
    sc = Scenario("promote_mid_partition", [
        Window(MembershipChaos(period=0.25, adds=2, removes=0), at=0.15,
               until=1.0),
        Window(PartialPartition(), at=0.2, until=0.9),
    ])
    res = churn_run(mode, sc, seed=13)
    assert check_linearizability(res.history) > 0
    assert any("learner" in ev for _, ev in sc.ctx.trace)


@pytest.mark.parametrize("mode", [ReadMode.LEASEGUARD, ReadMode.READ_INDEX,
                                  ReadMode.QUORUM],
                         ids=["leaseguard", "readindex", "quorum"])
def test_remove_then_crash(mode):
    """A voter is removed (and decommissioned); shortly after, the
    leader crashes. The shrunken config must elect cleanly."""
    sc = Scenario("remove_then_crash", [
        Window(MembershipChaos(period=0.2, adds=0, removes=1), at=0.2,
               until=0.6),
        Window(CrashRestart(scope="leader", downtime=0.3), at=0.55),
    ])
    res = churn_run(mode, sc, seed=17)
    assert check_linearizability(res.history) > 0
    assert any("removed voter" in ev for _, ev in sc.ctx.trace)


@pytest.mark.parametrize("mode", [ReadMode.LEASEGUARD, ReadMode.READ_INDEX,
                                  ReadMode.QUORUM],
                         ids=["leaseguard", "readindex", "quorum"])
def test_wipe_then_learner_rejoin(mode):
    """The safe disk-loss protocol end-to-end: crash, demote-while-down,
    wiped restart as forced learner, catch up, promote."""
    sc = build_scenario("disk_loss_safe")
    res = churn_run(mode, sc, seed=5)
    assert check_linearizability(res.history) > 0
    assert any("demoted wiped node" in ev for _, ev in sc.ctx.trace)
    assert any("wiped learner" in ev for _, ev in sc.ctx.trace)


def test_wiped_node_stays_nonvoting_until_promoted():
    """Scenario-level version of the acceptance criterion: while the
    wiped node is catching up it is a learner everywhere — no vote
    grants, no majority contribution."""
    from repro.core import build_cluster
    raft = RaftParams(read_mode=ReadMode.LEASEGUARD, election_timeout=0.3,
                      election_jitter=0.1, heartbeat_interval=0.03,
                      lease_duration=0.6, rpc_timeout=0.15)
    c = build_cluster(raft, SimParams(seed=5))
    ldr = c.wait_for_leader()
    run = lambda coro: c.loop.run_until_complete(c.loop.create_task(coro))
    for i in range(10):
        assert run(ldr.client_write("k", i)).ok
    victim = next(n for n in c.nodes.values() if n is not ldr)
    victim.crash()
    assert run(ldr.change_membership(
        set(ldr.config) - {victim.id},
        learners=set(ldr.learners) | {victim.id})).ok
    victim.restart(wipe_disk=True, rejoin_as_learner=True)
    # sample the invariant densely through catch-up and promotion
    deadline = c.loop.now + 3.0
    while c.loop.now < deadline:
        if victim.id not in ldr.config:          # not yet promoted
            assert victim.is_learner()
            assert victim.id not in {ldr.id} | set(ldr.config) \
                or ldr.majority() <= len(ldr.config) // 2 + 1
            assert ldr.majority() == 2           # voters are the other two
        c.loop.run_until(c.loop.now + 0.01)
    assert victim.id in ldr.config               # eventually promoted
    assert not victim.is_learner()


# ------------------------------------------------------ property tests
@given(seed=st.integers(0, 10_000))
@settings(max_examples=6, deadline=None)
def test_random_membership_churn_keeps_leaseguard_linearizable(seed):
    sc = random_membership_scenario(seed)
    res = churn_run(ReadMode.LEASEGUARD, sc, seed=seed % 97)
    assert check_linearizability(res.history) >= 0


@given(seed=st.integers(0, 10_000),
       mode=st.sampled_from([ReadMode.READ_INDEX, ReadMode.QUORUM]))
@settings(max_examples=6, deadline=None)
def test_random_membership_churn_keeps_other_policies_linearizable(seed, mode):
    sc = random_membership_scenario(seed + 4242)
    res = churn_run(mode, sc, seed=seed % 89)
    assert check_linearizability(res.history) >= 0

"""Tracked simulator-performance baseline (``BENCH_simperf.json``).

The simulator is the instrument every other benchmark runs on, so its
speed is tracked like a result: this harness measures

* **matrix cell cost** — wall time per (policy, scenario, seed) cell of
  the fault matrix, cold (fresh boot + election per seed) and warm
  (``warm_start=True``, one snapshot amortized across seeds), over the
  fixed reference slice 2 policies x 2 scenarios x 3 seeds;
* **event-loop throughput** — events/sec and simulated-seconds per
  wall-second for one representative run per policy.

Wall times are normalized by a deterministic CPU calibration loop so the
committed artifact is comparable across machines: ``*_per_calib`` is
"cell cost in units of the calibration workload", which is what
``--check`` compares (CI fails if a push regresses it by >30%).

Usage:
    python benchmarks/simperf.py [--smoke] [--check] [--repeat N] [--out P]

``--smoke`` does one repetition and writes ``BENCH_simperf_smoke.json``
(gitignored) instead of the committed artifact; ``--check`` additionally
compares against the committed ``BENCH_simperf.json`` and exits nonzero
on regression. CI runs ``--smoke --check`` on every push.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from repro.core import RaftParams, SimParams, run_workload  # noqa: E402
from repro.core.runner import clear_warm_cache  # noqa: E402

from benchmarks.fault_matrix import run_cell  # noqa: E402
from benchmarks.fault_matrix import policy_configs  # noqa: E402
from repro.consistency import split_bench_config  # noqa: E402

REPO_ROOT = Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_simperf.json"
SMOKE_OUT_PATH = REPO_ROOT / "BENCH_simperf_smoke.json"

#: reference matrix slice — mixed failover + network-fault cells; the
#: same slice measured pre-optimization gives PRE_PR_S_PER_CELL below
SLICE = [(p, s, seed)
         for p in ("leaseguard", "quorum")
         for s in ("leader_crash_restart", "flaky_network")
         for seed in range(3)]

#: wall seconds per SLICE cell on this repo immediately before the
#: fast-path PR (same machine as the committed artifact) — the
#: improvement denominator
PRE_PR_S_PER_CELL = 0.1008

#: policies for the event-loop throughput section
THROUGHPUT_POLICIES = ("inconsistent", "quorum", "readindex", "leaseguard")

REGRESSION_TOLERANCE = 1.30     # --check fails beyond +30%
#: flight-recorder budget: tracing ON may cost at most this fraction
#: extra per cell (and OFF must be free — it rides on every run)
TRACE_OVERHEAD_MAX = 0.10


def calibrate() -> float:
    """Deterministic CPU workload (~tens of ms) used as the wall-time
    normalizer; returns its duration in seconds."""
    t0 = time.perf_counter()
    acc = 0
    for i in range(400_000):
        acc = (acc * 1103515245 + i) % 2_147_483_647
    if acc < 0:     # unreachable; keeps the loop from being elided
        print(acc)
    return time.perf_counter() - t0


def measure_matrix(repeat: int) -> dict:
    """Cold vs warm wall time per cell over the reference SLICE."""
    run_cell(*SLICE[0])                       # JIT-less warmup (imports, caches)
    cold_best = min(
        _timed(lambda: [run_cell(p, s, seed) for p, s, seed in SLICE])
        for _ in range(repeat))
    warm_best = None
    for _ in range(repeat):
        clear_warm_cache()                    # include snapshot build cost
        t = _timed(lambda: [run_cell(p, s, seed, warm_start=True)
                            for p, s, seed in SLICE])
        warm_best = t if warm_best is None else min(warm_best, t)
    n = len(SLICE)
    return {
        "slice_cells": n,
        "cold_s_per_cell": round(cold_best / n, 6),
        "warm_s_per_cell": round(warm_best / n, 6),
        "warm_speedup_vs_cold": round(cold_best / warm_best, 3),
        "pre_pr_s_per_cell": PRE_PR_S_PER_CELL,
        "cold_speedup_vs_pre_pr": round(PRE_PR_S_PER_CELL / (cold_best / n), 3),
        "warm_speedup_vs_pre_pr": round(PRE_PR_S_PER_CELL / (warm_best / n), 3),
    }


def measure_trace_overhead(repeat: int) -> dict:
    """Recording cost of the flight recorder (repro.obs) over the
    reference SLICE: the same ``run_workload`` calls untraced vs traced,
    no checker and no post-run analysis — isolating the instrumentation
    cost every traced run pays. Both passes run in one process back to
    back, so the *ratio* is machine-independent and ``--check`` can
    enforce it absolutely (< TRACE_OVERHEAD_MAX)."""
    from repro.faults import build_scenario

    def one_pass(trace: bool) -> float:
        def go():
            for p, s, seed in SLICE:
                flags, sim_flags = split_bench_config(policy_configs()[p])
                sc = build_scenario(s)
                raft = RaftParams(election_timeout=0.3, election_jitter=0.1,
                                  heartbeat_interval=0.03, lease_duration=0.6,
                                  rpc_timeout=0.15,
                                  **{**flags, **sc.raft_overrides})
                sim = SimParams(seed=seed, sim_duration=1.2,
                                interarrival=3e-3, write_fraction=1 / 3,
                                **sim_flags)
                run_workload(raft, sim, fault_script=sc.install, check=False,
                             settle_time=1.5, trace=trace)
        return _timed(go)

    # warm BOTH code paths (the tracer's emit path and its event-list
    # allocations are cold on first use — measuring it unwarmed inflates
    # the ratio several-fold), then interleave the passes so frequency
    # drift hits both sides equally
    one_pass(False)
    one_pass(True)
    pairs = [(one_pass(False), one_pass(True)) for _ in range(repeat)]
    off = min(p[0] for p in pairs)
    on = min(p[1] for p in pairs)
    # the enforced ratio is the BEST per-pair ratio: adjacent passes see
    # the same machine state, so a pair's ratio cancels frequency drift,
    # and scheduler/GC hiccups are strictly additive — every pair
    # OVERestimates the intrinsic cost except when a hiccup lands on its
    # untraced half, so min-of-pairs is the faithful estimate. A real
    # regression (an expensive emit) inflates every pair and still trips.
    frac = min(p[1] / p[0] for p in pairs)
    n = len(SLICE)
    return {
        "untraced_s_per_cell": round(off / n, 6),
        "traced_s_per_cell": round(on / n, 6),
        "trace_overhead_frac": round(max(0.0, frac - 1.0), 4),
    }


def measure_throughput(repeat: int) -> list[dict]:
    """Events/sec + simulated-s per wall-s, one plain run per policy."""
    rows = []
    for policy in THROUGHPUT_POLICIES:
        flags, sim_flags = split_bench_config(policy_configs()[policy])
        raft = RaftParams(election_timeout=0.3, election_jitter=0.1,
                          heartbeat_interval=0.03, lease_duration=0.6,
                          rpc_timeout=0.15, **flags)
        sim = SimParams(seed=0, sim_duration=1.2, interarrival=3e-3,
                        write_fraction=1 / 3, **sim_flags)
        best = None
        for _ in range(repeat):
            t0 = time.perf_counter()
            res = run_workload(raft, sim, check=False, settle_time=1.5)
            wall = time.perf_counter() - t0
            if best is None or wall < best[0]:
                best = (wall, res)
        wall, res = best
        rows.append({
            "policy": policy,
            "wall_s": round(wall, 6),
            "sim_s": round(res.t_end, 6),
            "sim_s_per_wall_s": round(res.t_end / wall, 1),
            "events": res.loop_stats["events_popped"],
            "events_per_s": round(res.loop_stats["events_popped"] / wall),
            "peak_heap": res.loop_stats["peak_heap"],
            "timers_reaped": res.loop_stats["timers_reaped"],
            "messages_delivered": res.net_stats["messages_delivered"],
        })
    return rows


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def build_artifact(repeat: int) -> dict:
    # best-of-N on the calibration too: min wall time is far more stable
    # than a single sample on a shared/loaded host
    calib = min(calibrate() for _ in range(max(3, repeat)))
    matrix = measure_matrix(repeat)
    matrix["cold_per_calib"] = round(matrix["cold_s_per_cell"] / calib, 3)
    matrix["warm_per_calib"] = round(matrix["warm_s_per_cell"] / calib, 3)
    return {
        "calibration_s": round(calib, 6),
        "repeat": repeat,
        "matrix": matrix,
        "trace": measure_trace_overhead(repeat),
        "throughput": measure_throughput(repeat),
    }


def check_regression(artifact: dict, baseline_path: Path) -> list[str]:
    """Compare cell cost against the committed baseline; returns
    human-readable failures (empty = within budget).

    A mode only fails when BOTH the raw wall time and the
    calibration-normalized cost exceed the budget: a slower machine
    inflates raw but not normalized (the calibration loop slows with
    it), while CPU-frequency jitter can inflate normalized but not raw —
    only a genuine simulator regression inflates both."""
    if not baseline_path.exists():
        return [f"no committed baseline at {baseline_path}"]
    base = json.loads(baseline_path.read_text())
    problems = []
    for mode in ("cold", "warm"):
        raw_now = artifact["matrix"][f"{mode}_s_per_cell"]
        raw_ref = base["matrix"][f"{mode}_s_per_cell"]
        cal_now = artifact["matrix"][f"{mode}_per_calib"]
        cal_ref = base["matrix"][f"{mode}_per_calib"]
        if (raw_now > raw_ref * REGRESSION_TOLERANCE
                and cal_now > cal_ref * REGRESSION_TOLERANCE):
            problems.append(
                f"{mode}: {raw_now * 1e3:.1f} ms/cell vs baseline "
                f"{raw_ref * 1e3:.1f} (+{(raw_now / raw_ref - 1) * 100:.0f}%)"
                f", normalized {cal_now} vs {cal_ref} "
                f"(+{(cal_now / cal_ref - 1) * 100:.0f}%); budget +30%")
    # the flight-recorder budget is absolute (self-ratio, machine-free):
    # tracing must cost < TRACE_OVERHEAD_MAX per cell when enabled
    tr = artifact.get("trace")
    if tr is not None and tr["trace_overhead_frac"] > TRACE_OVERHEAD_MAX:
        problems.append(
            f"trace: +{tr['trace_overhead_frac'] * 100:.1f}% per traced "
            f"cell ({tr['untraced_s_per_cell'] * 1e3:.1f} -> "
            f"{tr['traced_s_per_cell'] * 1e3:.1f} ms); budget "
            f"+{TRACE_OVERHEAD_MAX * 100:.0f}%")
    return problems


def run(quick: bool = False) -> list[dict]:
    """benchmarks.run entry point; returns the per-policy throughput rows."""
    artifact = main(["--smoke", "--check"] if quick else [])
    return artifact["throughput"]


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="best-of-3 timing; write the gitignored smoke artifact")
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) if cell cost regressed >30% vs the "
                         "committed BENCH_simperf.json")
    ap.add_argument("--repeat", type=int, default=None,
                    help="timing repetitions, best-of (default 3)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    repeat = args.repeat or 3
    artifact = build_artifact(repeat)
    out_path = Path(args.out) if args.out else (
        SMOKE_OUT_PATH if args.smoke else OUT_PATH)
    out_path.write_text(json.dumps(artifact, indent=2, sort_keys=True) + "\n")
    print(f"# wrote {out_path}", file=sys.stderr)

    m = artifact["matrix"]
    print(f"matrix cell (cold): {m['cold_s_per_cell'] * 1e3:7.1f} ms "
          f"({m['cold_speedup_vs_pre_pr']:.2f}x vs pre-optimization)")
    print(f"matrix cell (warm): {m['warm_s_per_cell'] * 1e3:7.1f} ms "
          f"({m['warm_speedup_vs_pre_pr']:.2f}x vs pre-optimization)")
    tr = artifact["trace"]
    print(f"matrix cell (traced): {tr['traced_s_per_cell'] * 1e3:5.1f} ms "
          f"(+{tr['trace_overhead_frac'] * 100:.1f}% vs untraced "
          f"{tr['untraced_s_per_cell'] * 1e3:.1f} ms)")
    for r in artifact["throughput"]:
        print(f"{r['policy']:14s} {r['sim_s_per_wall_s']:7.1f} sim-s/wall-s "
              f"{r['events_per_s']:>9,d} events/s")

    if args.check:
        problems = check_regression(artifact, OUT_PATH)
        if problems:
            print("\nFAIL: simulator perf regression:", file=sys.stderr)
            for p in problems:
                print(f"  {p}", file=sys.stderr)
            raise SimPerfError("; ".join(problems))
        print("# perf within budget of committed baseline", file=sys.stderr)
    return artifact


class SimPerfError(AssertionError):
    """Cell cost regressed beyond REGRESSION_TOLERANCE vs the committed
    baseline (calibration-normalized, so machine speed mostly cancels)."""


if __name__ == "__main__":
    try:
        main()
    except SimPerfError:
        sys.exit(1)

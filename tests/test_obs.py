"""Flight recorder (repro.obs): determinism, draw-order neutrality,
schema validity, the lease-safety probe, per-node metrics, and the
forensics pipeline (digest + explain CLI)."""

from __future__ import annotations

import json

import pytest

from repro.consistency import resolve_read_mode
from repro.core import RaftParams, SimParams, build_cluster, run_workload
from repro.core.runner import clear_warm_cache
from repro.faults import build_scenario
from repro.obs import (Metrics, Tracer, at_most_one_lease_holder,
                       derive_headline_series, validate_events,
                       validate_jsonl)
from repro.obs.explain import main as explain_main
from repro.obs.explain import trace_digest
from repro.obs.export import read_jsonl, to_chrome_trace, write_jsonl
from repro.obs.metrics import _RAFT_COUNTERS


@pytest.fixture(autouse=True)
def _fresh_warm_cache():
    clear_warm_cache()
    yield
    clear_warm_cache()


def raftp(policy: str = "leaseguard", **kw) -> RaftParams:
    return RaftParams(read_mode=resolve_read_mode(policy),
                      election_timeout=0.3, election_jitter=0.1,
                      heartbeat_interval=0.03, lease_duration=0.6,
                      rpc_timeout=0.15, **kw)


def simp(seed: int, duration: float = 0.8) -> SimParams:
    return SimParams(seed=seed, sim_duration=duration, interarrival=3e-3,
                     write_fraction=1 / 3)


def fingerprint(res) -> list:
    return [(o.op_type, o.start_ts, o.end_ts, o.key, repr(o.value),
             o.success) for o in res.history]


def crash_run(policy: str, seed: int, trace: bool, warm: bool = False):
    sc = build_scenario("leader_crash_restart")
    return run_workload(raftp(policy, **sc.raft_overrides), simp(seed),
                        fault_script=sc.install, check=False,
                        settle_time=1.0, warm_start=warm, trace=trace)


# ------------------------------------------------------------- neutrality
def test_tracing_is_draw_order_neutral():
    """ON vs OFF: bit-identical histories AND loop/net/raft counters,
    cold and warm, under a leader crash."""
    off = crash_run("leaseguard", seed=3, trace=False)
    on = crash_run("leaseguard", seed=3, trace=True)
    assert fingerprint(off) == fingerprint(on)
    assert off.loop_stats == on.loop_stats
    assert off.net_stats == on.net_stats
    assert off.raft_stats == on.raft_stats
    assert off.trace is None and len(on.trace) > 100

    clear_warm_cache()
    w_off = crash_run("leaseguard", seed=3, trace=False, warm=True)
    clear_warm_cache()
    w_on = crash_run("leaseguard", seed=3, trace=True, warm=True)
    assert fingerprint(w_off) == fingerprint(w_on)
    assert w_off.loop_stats == w_on.loop_stats


def test_tracing_draws_nothing_from_any_prng():
    """Drive two identical clusters — one traced — and compare the
    internal state of every PRNG stream afterwards: the tracer must not
    have consumed a single draw anywhere."""
    def settled(trace: bool):
        cluster = build_cluster(raftp(), simp(5))
        if trace:
            Tracer(cluster.loop)
        cluster.wait_for_leader()
        cluster.loop.run_until(cluster.loop.now + 1.0)
        return cluster

    a, b = settled(False), settled(True)
    assert a.prng._r.getstate() == b.prng._r.getstate()
    assert a.net.prng._r.getstate() == b.net.prng._r.getstate()
    for nid in a.nodes:
        assert (a.nodes[nid].prng._r.getstate()
                == b.nodes[nid].prng._r.getstate())
        assert (a.nodes[nid].clock.prng._r.getstate()
                == b.nodes[nid].clock.prng._r.getstate())
    assert len(b.loop.tracer.events) > 0


# ------------------------------------------------------------ determinism
def test_jsonl_byte_identical_across_runs(tmp_path):
    paths = []
    for i in range(2):
        res = crash_run("leaseguard", seed=7, trace=True)
        p = tmp_path / f"run{i}.jsonl"
        write_jsonl(res.trace, p, seed=7, scenario="leader_crash_restart")
        paths.append(p)
    assert paths[0].read_bytes() == paths[1].read_bytes()
    assert validate_jsonl(paths[0]) == []


# ----------------------------------------------------------------- schema
def test_traced_run_validates_and_exports_chrome():
    res = crash_run("leaseguard", seed=2, trace=True)
    assert validate_events(res.trace) == []
    types = {e["type"] for e in res.trace}
    # a crash-and-reelect run exercises the core taxonomy
    for t in ("role", "election", "vote", "commit", "lease", "read",
              "write", "fault"):
        assert t in types, f"missing event type {t}"
    chrome = to_chrome_trace(res.trace, t_end=res.t_end)
    json.dumps(chrome)                      # serializable
    evs = chrome["traceEvents"]
    assert any(e["ph"] == "M" for e in evs)
    assert any(e["ph"] == "X" and e["name"].startswith("leader") for e in evs)
    assert any(e["ph"] == "X" and "lease" in e["name"] for e in evs)


def test_causal_parents_reach_the_election():
    """A post-crash read on the deposed node must causally chain to a
    role event (its context), and the trace orders ids/parents sanely."""
    res = crash_run("leaseguard", seed=2, trace=True)
    by_id = {e["id"]: e for e in res.trace}
    fails = [e for e in res.trace
             if e["type"] == "read" and e["op"] == "fail"]
    assert fails, "crash run produced no failed reads to explain"
    for f in fails:
        start = by_id[f["parent"]]
        assert start["type"] == "read" and start["op"] == "start"
        if start["parent"] is not None:
            assert by_id[start["parent"]]["type"] == "role"


# ------------------------------------------------------------------ probe
def test_lease_probe_passes_on_consistent_crash_runs():
    for seed in (0, 1, 2):
        res = crash_run("leaseguard", seed=seed, trace=True)
        assert at_most_one_lease_holder(res.trace) == []


def test_lease_probe_catches_synthetic_overlap():
    def lease(i, t, node, term, entry_term, until):
        return {"id": i, "t": t, "type": "lease", "node": node,
                "term": term, "parent": None, "op": "acquire",
                "entry_term": entry_term, "until": until, "limbo": 0}

    # node 1 opens an own-term window at t=1.0 while node 0's own-term
    # window is valid until t=1.5 -> exclusive overlap
    overlap = [lease(1, 0.5, 0, 1, 1, 1.5), lease(2, 1.0, 1, 2, 2, 2.0)]
    v = at_most_one_lease_holder(overlap)
    assert len(v) == 1 and v[0]["check"] == "exclusive_window_overlap"

    # same windows but the second is INHERITED (entry_term < term): safe
    inherited = [lease(1, 0.5, 0, 1, 1, 1.5), lease(2, 1.0, 1, 2, 1, 2.0)]
    assert at_most_one_lease_holder(inherited) == []

    # relinquish before the successor opens: planned handover, safe
    handover = [lease(1, 0.5, 0, 1, 1, 1.5),
                {"id": 2, "t": 0.8, "type": "lease", "node": 0, "term": 1,
                 "parent": None, "op": "relinquish"},
                lease(3, 1.0, 1, 2, 2, 2.0)]
    assert at_most_one_lease_holder(handover) == []

    # two nodes emitting windows at the same term: split brain
    twins = [lease(1, 0.5, 0, 3, 3, 1.5), lease(2, 0.6, 1, 3, 3, 1.6)]
    checks = {x["check"] for x in at_most_one_lease_holder(twins)}
    assert "one_leader_per_term" in checks


# ---------------------------------------------------------------- metrics
def test_per_node_raft_stats_sum_to_totals():
    res = crash_run("leaseguard", seed=4, trace=False)
    assert res.raft_by_node, "per-node breakdown missing"
    for name in _RAFT_COUNTERS:
        assert (sum(row[name] for row in res.raft_by_node.values())
                == res.raft_stats[name])
    assert (max(row["term"] for row in res.raft_by_node.values())
            == res.raft_stats["max_term"])
    # historical key order is part of the artifact contract
    assert list(res.loop_stats) == ["events_popped", "timers_scheduled",
                                    "timers_reaped", "pending", "peak_heap",
                                    "now"]
    assert list(res.raft_stats) == ["max_term", *_RAFT_COUNTERS]
    assert isinstance(res.metrics, Metrics)


def test_headline_series_are_sane():
    res = crash_run("leaseguard", seed=2, trace=True)
    s = derive_headline_series(res.trace, res.t_start, res.t_end)
    assert 0.0 < s["leader_uptime_fraction"] <= 1.0
    assert 0.0 < s["lease_coverage"] <= 1.0
    assert s["read_stalls"]["count"] > 0
    assert len(s["leader_timeline"]) >= 2       # crash forces a re-election
    assert any(d["lag"] is not None for d in s["fault_detection"])


# -------------------------------------------------------------- forensics
def test_digest_and_explain_cli(tmp_path, capsys):
    res = crash_run("leaseguard", seed=0, trace=True)
    d = trace_digest(res.trace, res.t_start, res.t_end)
    assert d["n_elections"] >= 2 and d["faults"]
    assert d["lease_probe_violations"] == 0
    json.dumps(d)

    p = tmp_path / "t.jsonl"
    write_jsonl(res.trace, p, seed=0)
    rc = explain_main([str(p), "--validate", "--probe"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "schema: OK" in out and "lease probe: OK" in out
    head, events = read_jsonl(p)
    assert head["seed"] == 0 and len(events) == len(res.trace)


def test_fault_matrix_cell_embeds_digest_on_violation():
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent
                           / "benchmarks"))
    from fault_matrix import run_cell

    row = run_cell("inconsistent", "majority_minority", 1)
    assert row["violation"], "expected the known flagged cell to flag"
    d = row["trace_digest"]
    assert d["stale_suspects"] > 0
    assert any("election won by node" in c for c in d["causes"])

    traced = run_cell("leaseguard", "leader_crash_restart", 0, trace=True)
    assert traced["lease_probe_violations"] == 0
    assert traced["trace_events"] > 100
    # traced rows carry the exact same history-derived fields
    untraced = run_cell("leaseguard", "leader_crash_restart", 0)
    for k in ("ops_ok", "ops_fail", "availability", "checked_ops",
              "violation", "timeline"):
        assert traced[k] == untraced[k]


# ------------------------------------------------------------------ fleet
def test_fleet_tracing_is_neutral_and_structured():
    from repro.fleet import (FleetParams, build_fleet_scenario, run_fleet)

    def go(trace: bool):
        return run_fleet(raftp(), SimParams(seed=1),
                         FleetParams(duration=2.0),
                         build_fleet_scenario("chief_kill"), trace=trace)

    off, on = go(False), go(True)
    assert off.summarize() == on.summarize()
    assert off.events == [] and validate_events(on.events) == []
    ops = {e["op"] for e in on.events if e["type"] == "fleet"}
    assert {"claim", "manifest", "restore"} <= ops

"""qwen3-8b — dense, GQA kv=8, qk_norm. [hf:Qwen/Qwen3-8B; hf]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    grad_accum=4,
    source="hf:Qwen/Qwen3-8B",
)

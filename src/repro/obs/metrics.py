"""The unified Metrics registry + derived per-run series.

:class:`Metrics` supersedes the ad-hoc ``loop_stats`` / ``net_stats`` /
``raft_stats`` dicts that ``run_workload`` used to assemble by hand:
every series is registered under its historical name, keyed by node id
where per-node attribution exists (the ad-hoc ``raft_stats`` summed
across nodes and lost it — the counter-drift fix). The compatibility
accessors (:meth:`Metrics.loop_stats` etc.) reproduce the old dicts
key-for-key so existing artifacts and tests are unchanged, and
:meth:`Metrics.raft_stats_by_node` exposes the per-node breakdown the
matrix artifacts now embed.

The second half of the module derives headline series from a recorded
trace (a list of event dicts, see :mod:`repro.obs.schema`):

* :func:`leader_timeline` / :func:`leader_uptime_fraction`
* :func:`lease_coverage`
* :func:`read_stall_histogram`
* :func:`election_to_first_commit`
* :func:`fault_detection_latency` (e.g. CheckQuorum step-down lag)

bundled by :func:`derive_headline_series`. All pure functions over the
trace: they never touch the simulation.
"""

from __future__ import annotations

from typing import Optional

#: per-node protocol counters, in the historical raft_stats order
_RAFT_COUNTERS = ("elections_started", "prevote_rounds", "leader_evictions",
                  "healthy_evictions", "quorum_step_downs", "checksum_drops")


class Metrics:
    """Counters, gauges, and sim-time histograms keyed by (name, node).

    ``node=None`` is the cluster-/loop-level key. Values are plain
    numbers; histograms store their observations (runs are short enough
    that exact percentiles beat bucketed sketches).
    """

    def __init__(self) -> None:
        self._counters: dict[str, dict[Optional[int], float]] = {}
        self._gauges: dict[str, dict[Optional[int], float]] = {}
        self._hists: dict[str, dict[Optional[int], list[float]]] = {}

    # -- writers -----------------------------------------------------------
    def inc(self, name: str, node: Optional[int] = None,
            value: float = 1) -> None:
        series = self._counters.setdefault(name, {})
        series[node] = series.get(node, 0) + value

    def gauge(self, name: str, value: float,
              node: Optional[int] = None) -> None:
        self._gauges.setdefault(name, {})[node] = value

    def observe(self, name: str, value: float,
                node: Optional[int] = None) -> None:
        self._hists.setdefault(name, {}).setdefault(node, []).append(value)

    # -- readers -----------------------------------------------------------
    def counter(self, name: str, node: Optional[int] = None) -> float:
        return self._counters.get(name, {}).get(node, 0)

    def counter_total(self, name: str) -> float:
        return sum(self._counters.get(name, {}).values())

    def gauge_value(self, name: str, node: Optional[int] = None) -> float:
        return self._gauges.get(name, {}).get(node, 0)

    def gauge_max(self, name: str) -> float:
        series = self._gauges.get(name, {})
        return max(series.values()) if series else 0

    def by_node(self, name: str) -> dict:
        merged: dict = {}
        merged.update(self._counters.get(name, {}))
        merged.update(self._gauges.get(name, {}))
        return {k: v for k, v in sorted(merged.items(),
                                        key=lambda kv: (kv[0] is None, kv[0]))
                if k is not None}

    def histogram(self, name: str, node: Optional[int] = None) -> list[float]:
        return self._hists.get(name, {}).get(node, [])

    # -- absorption from a finished run ------------------------------------
    @classmethod
    def from_cluster(cls, cluster) -> "Metrics":
        """Absorb the loop / network / per-node protocol counters of a
        finished (or running) cluster. Reading counters never perturbs
        the simulation."""
        m = cls()
        loop = cluster.loop
        m.inc("events_popped", value=loop.events_popped)
        m.inc("timers_scheduled", value=loop.timers_scheduled)
        m.inc("timers_reaped", value=loop.timers_reaped)
        m.gauge("pending", len(loop._heap))
        m.gauge("peak_heap", loop.peak_heap)
        m.gauge("now", loop.now)
        net = cluster.net
        m.inc("messages_sent", value=net.messages_sent)
        m.inc("messages_delivered", value=net.messages_delivered)
        m.inc("messages_dropped", value=net.messages_dropped)
        m.inc("bytes_sent", value=net.bytes_sent)
        for nid, n in sorted(cluster.nodes.items()):
            m.gauge("term", n.term, node=nid)
            for name in _RAFT_COUNTERS:
                m.inc(name, node=nid, value=getattr(n, name))
        return m

    # -- compatibility accessors (the historical dicts, key-for-key) -------
    def loop_stats(self) -> dict:
        return {
            "events_popped": self.counter_total("events_popped"),
            "timers_scheduled": self.counter_total("timers_scheduled"),
            "timers_reaped": self.counter_total("timers_reaped"),
            "pending": self.gauge_value("pending"),
            "peak_heap": self.gauge_value("peak_heap"),
            "now": self.gauge_value("now"),
        }

    def net_stats(self) -> dict:
        return {
            "messages_sent": self.counter_total("messages_sent"),
            "messages_delivered": self.counter_total("messages_delivered"),
            "messages_dropped": self.counter_total("messages_dropped"),
            "bytes_sent": self.counter_total("bytes_sent"),
        }

    def raft_stats(self) -> dict:
        out = {"max_term": self.gauge_max("term")}
        for name in _RAFT_COUNTERS:
            out[name] = self.counter_total(name)
        return out

    def raft_stats_by_node(self) -> dict:
        """{node_id: {"term": ..., counter: ...}} — the per-node
        attribution the summed raft_stats lose."""
        out: dict = {}
        for nid, term in self.by_node("term").items():
            row = {"term": term}
            for name in _RAFT_COUNTERS:
                row[name] = self.counter(name, node=nid)
            out[nid] = row
        return out


# ------------------------------------------------------------------ series


def leader_timeline(events: list, t_end: Optional[float] = None) -> list:
    """Leadership spans [{node, term, t0, t1}] from role events. A span
    opens at a ``role=leader`` event and closes at that node's next role
    event (deposed/stepped down/crashed) or ``t_end``."""
    spans: list[dict] = []
    open_by_node: dict[int, dict] = {}
    last_t = 0.0
    for e in events:
        last_t = e["t"]
        if e["type"] != "role":
            continue
        node = e["node"]
        cur = open_by_node.pop(node, None)
        if cur is not None:
            cur["t1"] = e["t"]
            spans.append(cur)
        if e["role"] == "leader":
            open_by_node[node] = {"node": node, "term": e["term"],
                                  "t0": e["t"], "t1": None}
    end = last_t if t_end is None else t_end
    for cur in open_by_node.values():
        cur["t1"] = max(end, cur["t0"])
        spans.append(cur)
    spans.sort(key=lambda s: (s["t0"], s["node"]))
    return spans


def _union(intervals: list, t0: float, t1: float) -> float:
    """Total length of the union of [a, b] intervals clipped to [t0, t1]."""
    clipped = sorted((max(a, t0), min(b, t1)) for a, b in intervals)
    covered, cursor = 0.0, t0
    for a, b in clipped:
        if b <= cursor:
            continue
        covered += b - max(a, cursor)
        cursor = b
    return covered


def leader_uptime_fraction(events: list, t0: float, t1: float) -> float:
    """Fraction of [t0, t1] during which some node held leadership."""
    if t1 <= t0:
        return 0.0
    spans = leader_timeline(events, t_end=t1)
    return _union([(s["t0"], s["t1"]) for s in spans], t0, t1) / (t1 - t0)


def lease_coverage(events: list, t0: float, t1: float) -> float:
    """Fraction of [t0, t1] covered by some lease window: each
    acquire/extend event opens [t, until]. An upper bound on when local
    reads could be served without a round trip — the paper's
    '99% of reads' claim is this series staying near 1 across failovers."""
    if t1 <= t0:
        return 0.0
    windows = [(e["t"], e["until"]) for e in events
               if e["type"] == "lease" and e["op"] in ("acquire", "extend")]
    return _union(windows, t0, t1) / (t1 - t0)


def read_stall_histogram(events: list) -> dict:
    """Distribution of read stall durations (start→done/fail) in seconds.
    ``bins`` are cumulative ("le" = upper bound in seconds)."""
    stalls = sorted(e["stall"] for e in events
                    if e["type"] == "read" and e["op"] in ("done", "fail"))
    bounds = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1, 1.0,
              float("inf"))
    bins = [{"le": b, "count": 0} for b in bounds]
    for s in stalls:
        for b in bins:
            if s <= b["le"]:
                b["count"] += 1

    def pct(q: float) -> float:
        if not stalls:
            return float("nan")
        return stalls[min(len(stalls) - 1, int(q * len(stalls)))]

    return {"count": len(stalls),
            "p50": pct(0.50), "p90": pct(0.90), "p99": pct(0.99),
            "max": stalls[-1] if stalls else float("nan"),
            "bins": bins}


def election_to_first_commit(events: list) -> list:
    """Per leadership: latency from winning the election to the first
    commit advancement at that term — the write-unavailability window a
    failover costs (LeaseGuard's commit gate makes it visible)."""
    out = []
    pending: dict[int, dict] = {}
    for e in events:
        if e["type"] == "role" and e["role"] == "leader":
            pending[e["node"]] = e
        elif e["type"] == "role":
            pending.pop(e["node"], None)
        elif e["type"] == "commit":
            start = pending.pop(e["node"], None)
            if start is not None and e["term"] == start["term"]:
                out.append({"node": e["node"], "term": e["term"],
                            "t_elected": start["t"],
                            "latency": e["t"] - start["t"]})
    return out


def fault_detection_latency(events: list) -> list:
    """For each fault activation, the lag until the cluster visibly
    reacted: the first CheckQuorum step-down, eviction, or new campaign
    after the fault started. None = never detected within the trace."""
    reactions = [e for e in events if e["type"] == "role"
                 and (e["role"] == "candidate"
                      or e["reason"] in ("check_quorum", "deposed"))]
    out = []
    for e in events:
        if e["type"] != "fault" or e["op"] != "start":
            continue
        hit = next((r for r in reactions if r["t"] >= e["t"]), None)
        out.append({"fault": e["label"], "t": e["t"],
                    "detected_t": hit["t"] if hit else None,
                    "lag": (hit["t"] - e["t"]) if hit else None,
                    "via": (f"node {hit['node']} "
                            f"{hit['role']}/{hit['reason']}" if hit
                            else None)})
    return out


def derive_headline_series(events: list, t0: float, t1: float) -> dict:
    """The bundle the benchmarks and the explain CLI report."""
    return {
        "leader_timeline": leader_timeline(events, t_end=t1),
        "leader_uptime_fraction": leader_uptime_fraction(events, t0, t1),
        "lease_coverage": lease_coverage(events, t0, t1),
        "read_stalls": read_stall_histogram(events),
        "election_to_first_commit": election_to_first_commit(events),
        "fault_detection": fault_detection_latency(events),
    }

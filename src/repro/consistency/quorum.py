"""Quorum reads: Raft's default consistency (paper §6, "quorum").

Every read pays one majority round (an empty AppendEntries barrier) to
confirm the node is still leader, then waits for its applied state to
catch up to the commit index observed at arrival. Linearizable, but each
read costs a full round trip and competes with replication for I/O —
the effect behind the paper's Figs. 9-11 throughput gap.
"""

from __future__ import annotations

from ..core.raft import ReadResult
from .base import ConsistencyPolicy


class QuorumPolicy(ConsistencyPolicy):
    name = "quorum"

    async def gate_read(self, key: str) -> ReadResult:
        n = self.node
        if not n.is_leader():
            return ReadResult(False, error="not_leader")
        term0 = n.term
        if not await self._confirm_leadership():
            return ReadResult(False, error="no_quorum")
        return await self._local_read(key, term0)

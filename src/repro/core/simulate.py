"""Deterministic discrete-event simulator (paper §6.1, ``simulate.py``).

An event loop with callbacks scheduled at future simulated times, plus a
task/future/coroutine layer similar to Python's asyncio — but fully
deterministic: given a seed and parameters, every run executes the same
events in the same order.

Time is a float in **seconds** of simulated "true time". Nodes never read
this directly; they use :class:`repro.core.clock.BoundedClock`, which wraps
true time in an uncertainty interval.
"""

from __future__ import annotations

import heapq
import inspect
from typing import Any, Callable, Coroutine, Iterable, Optional


class EventLoop:
    """A deterministic event loop over simulated time."""

    def __init__(self) -> None:
        self._heap: list[tuple[float, int, Callable[[], None]]] = []
        self._seq = 0  # tie-breaker: FIFO among same-deadline callbacks
        self.now: float = 0.0
        self._stopped = False

    # -- scheduling ------------------------------------------------------
    def call_at(self, when: float, fn: Callable[[], None]) -> None:
        if when < self.now:
            when = self.now
        heapq.heappush(self._heap, (when, self._seq, fn))
        self._seq += 1

    def call_later(self, delay: float, fn: Callable[[], None]) -> None:
        self.call_at(self.now + max(0.0, delay), fn)

    def call_soon(self, fn: Callable[[], None]) -> None:
        self.call_at(self.now, fn)

    # -- running ---------------------------------------------------------
    def _step(self) -> bool:
        if not self._heap:
            return False
        when, _, fn = heapq.heappop(self._heap)
        self.now = max(self.now, when)
        fn()
        return True

    def run_until(self, deadline: float) -> None:
        """Run events with time <= deadline; advance clock to deadline."""
        while self._heap and self._heap[0][0] <= deadline and not self._stopped:
            self._step()
        self.now = max(self.now, deadline)

    def run_until_complete(self, fut: "Future", max_time: float = float("inf")):
        while not fut.done():
            if self._stopped or not self._heap or self._heap[0][0] > max_time:
                raise RuntimeError(
                    f"future not resolved by t={self.now:.6f} "
                    f"(heap={'empty' if not self._heap else 'future events'})"
                )
            self._step()
        return fut.result()

    def run(self, max_time: float = float("inf")) -> None:
        while self._heap and not self._stopped and self._heap[0][0] <= max_time:
            self._step()

    def stop(self) -> None:
        self._stopped = True

    # -- coroutine layer --------------------------------------------------
    def create_task(self, coro: Coroutine) -> "Task":
        return Task(self, coro)

    def sleep(self, delay: float) -> "Future":
        f = Future(self)
        self.call_later(delay, lambda: f.set_result(None) if not f.done() else None)
        return f


class Future:
    """Awaitable one-shot result container bound to an :class:`EventLoop`."""

    __slots__ = ("loop", "_done", "_result", "_exc", "_callbacks")

    def __init__(self, loop: EventLoop) -> None:
        self.loop = loop
        self._done = False
        self._result: Any = None
        self._exc: Optional[BaseException] = None
        self._callbacks: list[Callable[["Future"], None]] = []

    def done(self) -> bool:
        return self._done

    def set_result(self, value: Any) -> None:
        if self._done:
            raise RuntimeError("future already resolved")
        self._done = True
        self._result = value
        self._fire()

    def set_exception(self, exc: BaseException) -> None:
        if self._done:
            raise RuntimeError("future already resolved")
        self._done = True
        self._exc = exc
        self._fire()

    def _fire(self) -> None:
        cbs, self._callbacks = self._callbacks, []
        for cb in cbs:
            # run callbacks "soon" to keep a clean, deterministic stack
            self.loop.call_soon(lambda cb=cb: cb(self))

    def add_done_callback(self, cb: Callable[["Future"], None]) -> None:
        if self._done:
            self.loop.call_soon(lambda: cb(self))
        else:
            self._callbacks.append(cb)

    def result(self) -> Any:
        if not self._done:
            raise RuntimeError("future not done")
        if self._exc is not None:
            raise self._exc
        return self._result

    def __await__(self):
        if not self._done:
            yield self
        return self.result()


class Task(Future):
    """Drives a coroutine on the event loop. Awaitable like a Future."""

    def __init__(self, loop: EventLoop, coro: Coroutine) -> None:
        super().__init__(loop)
        assert inspect.iscoroutine(coro), coro
        self._coro = coro
        self._cancelled = False
        loop.call_soon(lambda: self._advance(None, None))

    def cancel(self) -> None:
        self._cancelled = True

    def _advance(self, value: Any, exc: Optional[BaseException]) -> None:
        if self._done:
            return
        if self._cancelled:
            self._coro.close()
            if not self._done:
                self.set_exception(CancelledError())
            return
        try:
            if exc is not None:
                awaited = self._coro.throw(exc)
            else:
                awaited = self._coro.send(value)
        except StopIteration as stop:
            self.set_result(stop.value)
            return
        except BaseException as e:  # noqa: BLE001 - propagate into the future
            self.set_exception(e)
            return
        assert isinstance(awaited, Future), f"can only await Futures, got {awaited!r}"

        def _resume(fut: Future) -> None:
            try:
                res = fut.result()
            except BaseException as e:  # noqa: BLE001
                self._advance(None, e)
            else:
                self._advance(res, None)

        awaited.add_done_callback(_resume)


class CancelledError(Exception):
    pass


class TimeoutError_(Exception):
    pass


async def wait_for(fut: Future, timeout: float) -> Any:
    """Await ``fut`` with a simulated-time timeout."""
    loop = fut.loop
    waiter = Future(loop)

    def _on_done(f: Future) -> None:
        if not waiter.done():
            waiter.set_result(("ok", f))

    def _on_timeout() -> None:
        if not waiter.done():
            waiter.set_result(("timeout", None))

    fut.add_done_callback(_on_done)
    loop.call_later(timeout, _on_timeout)
    kind, f = await waiter
    if kind == "timeout":
        raise TimeoutError_(f"timed out after {timeout}s")
    return f.result()


async def gather(futs: Iterable[Future]) -> list:
    return [await f for f in futs]


class Event:
    """An asyncio.Event lookalike over simulated time."""

    def __init__(self, loop: EventLoop) -> None:
        self.loop = loop
        self._set = False
        self._waiters: list[Future] = []

    def is_set(self) -> bool:
        return self._set

    def set(self) -> None:
        self._set = True
        ws, self._waiters = self._waiters, []
        for w in ws:
            if not w.done():
                w.set_result(None)

    def clear(self) -> None:
        self._set = False

    async def wait(self) -> None:
        if self._set:
            return
        f = Future(self.loop)
        self._waiters.append(f)
        await f


class Condition:
    """Broadcast wakeup: tasks await a predicate re-checked on notify."""

    def __init__(self, loop: EventLoop) -> None:
        self.loop = loop
        self._waiters: list[Future] = []

    def notify_all(self) -> None:
        ws, self._waiters = self._waiters, []
        for w in ws:
            if not w.done():
                w.set_result(None)

    async def wait(self, timeout: Optional[float] = None) -> None:
        """Wait for the next notify_all; with ``timeout``, give up after that
        much simulated time. The condition owns the timeout path so that a
        timed-out waiter is removed from the waiter list immediately — an
        idle leader parks here on every heartbeat tick, and leaving resolved
        futures behind until the next notify_all would grow the list without
        bound."""
        f = Future(self.loop)
        self._waiters.append(f)
        if timeout is not None:
            def _expire() -> None:
                if not f.done():
                    try:
                        self._waiters.remove(f)
                    except ValueError:
                        pass
                    f.set_result(None)
            self.loop.call_later(timeout, _expire)
        await f

    async def wait_until(self, predicate: Callable[[], bool]) -> None:
        while not predicate():
            await self.wait()

"""musicgen-large — decoder-only transformer over EnCodec tokens. The
EnCodec frontend is a STUB: input_specs() provides precomputed frame
embeddings. [arXiv:2306.05284; hf]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    embedding_stub=True,
    grad_accum=4,      # EnCodec frame embeddings from the stub
    source="arXiv:2306.05284",
)
